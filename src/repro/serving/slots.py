"""Slot pool + weighted-fair admission for the continuous-batching engine.

Continuous batching decodes over a fixed pool of ``max_slots`` slots —
the jitted step always sees the same ``(max_slots, ...)`` shapes — while
requests join and leave *per step* through an active-mask.  Everything in
this module is host-side bookkeeping around that pool:

* :class:`SeqState` — one request's decode progress (prompt, generated
  tokens, next cache position).  It outlives its slot: a preempted
  request's ``SeqState`` (plus its pages, held in
  :class:`~repro.serving.kvcache.PageAllocator`) is the whole resume
  ticket.
* :class:`SlotPool` — which request occupies which slot, free-slot
  lookup, and the deterministic preemption-victim pick.
* :class:`WeightedFairQueues` — smooth weighted round-robin over the
  per-class admission queues.  The fixed-batch engine drains strictly by
  priority, which starves ``batch`` under sustained ``gold`` load; here
  every class with queued work gets slots in proportion to its weight
  (default ``2^(n-1-i)`` from
  :meth:`repro.sensitivity.classes.ClassBook.drain_weights`), and latency
  guarantees move to the explicit SLO/preemption path instead of being an
  accident of drain order.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Iterable, Mapping

import numpy as np

__all__ = ["SeqState", "SlotPool", "WeightedFairQueues"]


@dataclass
class SeqState:
    """Decode progress of one admitted request.

    ``pos`` is the next cache position to feed: positions
    ``0 .. len(prompt)-2`` are prefill (the fed token is the prompt),
    every later step feeds the previously generated token and produces a
    new one.  The request is done after ``gen_len`` generated tokens —
    ``len(prompt) + gen_len - 1`` steps in total, all through the same
    jitted decode step (one code path, one trace)."""

    rid: int
    cls: str
    prompt: np.ndarray
    gen_len: int
    submitted_t: float
    pos: int = 0
    generated: list = field(default_factory=list)
    preempted: int = 0          # how many times this request was preempted
    ring_rows: dict | None = None   # per-layer ring-buffer snapshot while
    #                                 suspended (paged layers need none:
    #                                 their KV lives in the request's pages)
    # lifecycle timestamps (engine clock): the request-timeline breakdown
    # is computed host-side from these and emitted on req.done, so a
    # chain is auditable even when the trace clock is injected
    admitted_t: float | None = None     # first admission into a slot
    first_token_t: float | None = None  # the TTFT edge
    queue_wait_s: float = 0.0           # submission -> first admission
    suspended_at: float | None = None   # eviction time while preempted
    suspended_s: float = 0.0            # total suspension so far
    suspended_before_first_s: float = 0.0   # suspension during prefill

    @property
    def n_tokens(self) -> int:
        """Cache positions this request may ever touch (its page claim)."""
        return len(self.prompt) + self.gen_len

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.gen_len

    def next_token(self) -> int:
        p = len(self.prompt)
        return int(self.prompt[self.pos]) if self.pos < p \
            else int(self.generated[self.pos - p])

    def advance(self, sampled: int) -> tuple[bool, bool]:
        """Consume one step's output.  Returns ``(generated_now, was_first)``
        — whether this step produced a token, and whether it was the
        request's first (the TTFT edge)."""
        generates = self.pos >= len(self.prompt) - 1
        if generates and not self.done:
            self.generated.append(int(sampled))
            first = len(self.generated) == 1
        else:
            first = False
        self.pos += 1
        return generates, first

    def breakdown(self, done_t: float) -> dict:
        """The lifecycle time breakdown ``req.done`` carries: queueing,
        prefill (suspension excluded), decode (suspension excluded), and
        total suspension, in ms.  The four segments sum to ``total_ms``
        by construction."""
        ft = self.first_token_t if self.first_token_t is not None else done_t
        at = self.admitted_t if self.admitted_t is not None else ft
        susp_decode = self.suspended_s - self.suspended_before_first_s
        return {
            "queue_ms": round(1e3 * self.queue_wait_s, 3),
            "prefill_ms": round(
                1e3 * max(0.0, (ft - at) - self.suspended_before_first_s),
                3),
            "decode_ms": round(1e3 * max(0.0, (done_t - ft) - susp_decode),
                               3),
            "suspension_ms": round(1e3 * self.suspended_s, 3),
            "total_ms": round(1e3 * (done_t - self.submitted_t), 3),
        }


class SlotPool:
    """Occupancy map of the fixed decode-slot pool."""

    def __init__(self, n_slots: int) -> None:
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.n_slots = int(n_slots)
        self.slots: list[SeqState | None] = [None] * self.n_slots

    def __iter__(self):
        """``(slot_idx, SeqState)`` for every occupied slot."""
        return ((i, s) for i, s in enumerate(self.slots) if s is not None)

    @property
    def n_active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def occupancy(self) -> float:
        return self.n_active / self.n_slots

    def free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def place(self, idx: int, seq: SeqState) -> None:
        assert self.slots[idx] is None, f"slot {idx} is occupied"
        self.slots[idx] = seq

    def evict(self, idx: int) -> SeqState:
        seq = self.slots[idx]
        assert seq is not None, f"slot {idx} is empty"
        self.slots[idx] = None
        return seq

    def pick_victim(self, priority_of: Callable[[str], int],
                    below: int) -> int | None:
        """The slot a higher-tier arrival preempts: deterministically the
        occupied slot whose class priority is *worst* (largest number)
        among those strictly below the arriving tier (``priority >
        below``), tie-broken toward the youngest request (largest rid —
        it has the least service invested and, having arrived last, the
        weakest claim).  ``None`` when no slot is preemptible."""
        best: tuple[int, int] | None = None
        best_idx = None
        for i, seq in self:
            p = priority_of(seq.cls)
            if p <= below:
                continue
            key = (p, seq.rid)
            if best is None or key > best:
                best, best_idx = key, i
        return best_idx


class WeightedFairQueues:
    """Smooth weighted round-robin over per-class admission queues.

    Classic SWRR restricted to *active* flows: each pick credits every
    class that has admissible queued work with its weight, takes the
    highest credit (ties resolve toward the earlier-declared — higher
    priority — class), and debits the winner by the total active weight.
    Over any busy window class shares converge to the weight ratio, and
    the whole schedule is a pure function of the arrival order — no RNG,
    so preemption/admission tests replay bit-identically."""

    def __init__(self, names: Iterable[str],
                 weights: Mapping[str, int] | None = None) -> None:
        self.names = tuple(names)
        if not self.names:
            raise ValueError("weighted-fair drain needs at least one class")
        w = dict(weights) if weights is not None else {}
        self.weights = {n: max(1, int(w.get(n, 1))) for n in self.names}
        self.queues: dict[str, deque] = {n: deque() for n in self.names}
        self._credit = {n: 0 for n in self.names}

    def __len__(self) -> int:
        return sum(len(q) for q in self.queues.values())

    @property
    def depth(self) -> int:
        return len(self)

    def push(self, name: str, item) -> None:
        self.queues[name].append(item)

    def push_front(self, name: str, item) -> None:
        """Resume path: a preempted request re-enters at the head of its
        class queue — it already waited its turn once."""
        self.queues[name].appendleft(item)

    def peek(self, name: str):
        q = self.queues[name]
        return q[0] if q else None

    def pop(self, name: str):
        return self.queues[name].popleft()

    def pick(self, admissible: Callable = lambda item: True):
        """Pop the next ``(class, item)`` under weighted-fair sharing,
        considering only classes whose *head* passes ``admissible``
        (e.g. "the page pool can cover it").  Returns ``None`` when no
        class has admissible work."""
        active = [n for n in self.names
                  if self.queues[n] and admissible(self.queues[n][0])]
        if not active:
            return None
        for n in active:
            self._credit[n] += self.weights[n]
        best = max(active, key=lambda n: self._credit[n])
        self._credit[best] -= sum(self.weights[n] for n in active)
        return best, self.queues[best].popleft()
