"""Library watcher: pick up store changes without stopping the server.

A background ``python -m repro.fleet`` sweep densifies the operator store
*while* the server decodes.  Between batches the engine polls the
watcher; when the store's :meth:`~repro.library.store.OperatorStore.version_token`
changes (records are content-addressed, so any put/merge/removal changes
the token), the watcher reloads the Pareto frontier and the runtime
atomically refreshes its plan — ``ParetoFrontier.from_store`` →
``qos.select_plan``/``refresh_plan`` → ``stack_luts`` — with shape/dtype
validation so a surprising store merge (different bit width) refuses to
swap instead of retracing the decode step.

Polling is rate-limited (``min_poll_s``) because a version check lists
the store directory; between-batch cadence on a busy server would stat
the filesystem far more often than libraries actually change.
"""

from __future__ import annotations

import time
from typing import Callable

from ..library.store import OperatorStore
from ..obs.metrics import MetricRegistry, get_registry

__all__ = ["LibraryWatcher"]


class LibraryWatcher:
    def __init__(self, library, *, min_poll_s: float = 2.0,
                 target_bits: int | None = None,
                 widths: tuple[int, ...] | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 registry: MetricRegistry | None = None) -> None:
        self.library = library
        self.store = OperatorStore(library)
        # the serving width is sticky across refreshes: a W8A8 serve must
        # reload the *8-bit composed* frontier, or every refresh would be
        # refused by the stack validator (16x16 vs 256x256).  A
        # mixed-width serve pins the whole width set instead and reloads
        # a merged MixedFrontier (the engine rebuilds its ladder inside
        # the frozen width map).
        assert target_bits is None or widths is None, \
            "target_bits (uniform) and widths (mixed) are exclusive"
        self.target_bits = target_bits
        self.widths = tuple(int(b) for b in widths) if widths else None
        self.min_poll_s = float(min_poll_s)
        self._clock = clock
        self._token = self.store.version_token()
        self._last_poll = clock()
        self.refreshes = 0
        # watcher health rides the process-wide registry by default so a
        # trace-dir metric snapshot answers "did the server ever see the
        # sweep land?" without grepping serve logs
        self._registry = registry if registry is not None else get_registry()

    @property
    def token(self) -> str:
        return self._token

    def poll(self) -> bool:
        """True when the store's contents changed since the last poll.
        Cheap no-op while the rate limit holds."""
        now = self._clock()
        if self.min_poll_s > 0 and now - self._last_poll < self.min_poll_s:
            return False
        self._last_poll = now
        self._registry.counter("watcher_polls_total").inc()
        token = self.store.version_token()
        if token == self._token:
            return False
        self._token = token
        self._registry.counter("watcher_changes_total").inc()
        return True

    def load_frontier(self):
        """(compiled frontier, exact_area, bits) of the refreshed store —
        the triple every plan-refresh path consumes, compiled at the
        watcher's serving width — or, for a mixed-width watcher, the
        merged :class:`~repro.precision.plans.MixedFrontier`.  Raises
        :class:`LookupError` if the store lost its multipliers (the
        caller keeps serving on the old plan)."""
        self.refreshes += 1
        self._registry.counter("watcher_refreshes_total").inc()
        if self.widths is not None:
            from ..precision.plans import load_mixed_frontier

            return load_mixed_frontier(self.library, self.widths)
        from ..library.compile import load_mul_frontier

        return load_mul_frontier(self.library, target_bits=self.target_bits)
