"""Serving telemetry: a thin view over the observability metric core.

The engine has exactly one recording path: every per-batch measurement
lands in a :class:`repro.obs.metrics.MetricRegistry` (counters for the
whole-run rates, per-class latency/throughput/drift *histograms* — so
``summary()`` can state per-class p50/p95/p99 ms-per-step, which a
mean-only row never could), and the bounded ring of raw per-batch events
is kept alongside for post-mortems — a long-running server never grows
the log without bound, while the registry aggregates stay exact across
ring wrap.  The *plan table* (plan id -> per-layer operator keys) and the
*swap log* are tiny and kept whole.

``summary()`` is the aggregate the bench trajectory ingests
(``BENCH_serve.json``); ``dump()`` writes the full document **atomically**
(parent dirs created, temp-file + ``os.replace``) so a mid-serve crash
never leaves a truncated JSON artifact.  The registry itself can be
snapshotted into a trace dir (``repro.obs.export.dump_metrics``) where
``python -m repro.obs`` merges it with fleet-side metrics.
"""

from __future__ import annotations

import time
from collections import deque
from pathlib import Path

from ..obs.export import write_bench_json
from ..obs.metrics import LATENCY_MS_BUCKETS, MetricRegistry

__all__ = ["Telemetry", "ALL_CLASSES", "TOK_S_BUCKETS", "DRIFT_BUCKETS",
           "TTFT_MS_BUCKETS", "WAIT_MS_BUCKETS"]

# the label the whole-run aggregate rides under; per-QoS-class rows appear
# next to it as classes are actually served (a single-tier serve stays
# clean: only "_all" exists)
ALL_CLASSES = "_all"

TOK_S_BUCKETS = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
                 1000.0, 2500.0, 5000.0, 10_000.0, 25_000.0, 100_000.0)
DRIFT_BUCKETS = (1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 0.01, 0.025, 0.05,
                 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)
# time-to-first-token spans queue wait + prefill, so it runs a couple of
# decades above per-step latency
TTFT_MS_BUCKETS = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
                   1000.0, 2500.0, 5000.0, 10_000.0, 30_000.0, 60_000.0)
# queueing delay and preemption-induced suspension share the TTFT scale
# but need sub-ms resolution: a healthy pool admits in microseconds
WAIT_MS_BUCKETS = (0.01, 0.05, 0.1, 0.5) + TTFT_MS_BUCKETS


class Telemetry:
    def __init__(self, capacity: int = 4096,
                 registry: MetricRegistry | None = None) -> None:
        self.capacity = int(capacity)
        self.events: deque[dict] = deque(maxlen=self.capacity)
        self.plans: dict[str, dict] = {}
        self.swaps: list[dict] = []
        # own registry by default: two engines (or two tests) in one
        # process must not cross-contaminate each other's counters
        self.registry = registry if registry is not None else MetricRegistry()
        self._t0 = time.time()

    # --------------------------------------------------------------- helpers
    def _count(self, name: str, cls: str | None, n: float) -> None:
        self.registry.counter(name, **{"class": ALL_CLASSES}).inc(n)
        if cls is not None:
            self.registry.counter(name, **{"class": cls}).inc(n)

    def _observe(self, name: str, cls: str | None, v: float,
                 buckets) -> None:
        self.registry.histogram(name, buckets=buckets,
                                **{"class": ALL_CLASSES}).observe(v)
        if cls is not None:
            self.registry.histogram(name, buckets=buckets,
                                    **{"class": cls}).observe(v)

    def _counter_value(self, name: str, cls: str = ALL_CLASSES) -> float:
        c = self.registry.find(name, **{"class": cls})
        return c.value if c is not None else 0.0

    def _cost_block(self, cls: str = ALL_CLASSES) -> dict | None:
        macs = self._counter_value("mlp_macs", cls)
        if not macs:
            return None
        lo = self.registry.find("area_mac_saved",
                                **{"class": cls, "layer": ALL_CLASSES})
        hi = self.registry.find("area_mac_saved_hi",
                                **{"class": cls, "layer": ALL_CLASSES})
        return {
            "mlp_macs": int(macs),
            "approx_macs": int(self._counter_value("approx_macs", cls)),
            "area_mac_saved": [
                round(lo.value if lo is not None else 0.0, 4),
                round(hi.value if hi is not None else 0.0, 4)],
        }

    # ------------------------------------------------------------------ write
    def register_plan(self, plan) -> str:
        """Record a :class:`~repro.library.qos.LayerPlan`'s identity once;
        batch events reference the short ``plan_id``."""
        pid = plan.plan_id
        if pid not in self.plans:
            self.plans[pid] = {
                "layers": [c.key or "exact" for c in plan.choices],
                "total_area": plan.total_area,
                "area_saving": plan.area_saving,
                "predicted_drift": plan.predicted_total,
                "budget": plan.budget,
            }
        return pid

    def record_batch(self, *, batch: int, tick: int, n_requests: int,
                     prefill_s: float, decode_s: float, prefill_tokens: int,
                     decode_tokens: int, decode_steps: int,
                     plan_id: str | None, drift: float | None = None,
                     backlog: int = 0, qos_class: str | None = None) -> None:
        self._count("serve_batches_total", qos_class, 1)
        self._count("serve_requests_total", qos_class, n_requests)
        self._count("serve_prefill_s_total", qos_class, prefill_s)
        self._count("serve_decode_s_total", qos_class, decode_s)
        self._count("serve_prefill_tokens_total", qos_class, prefill_tokens)
        self._count("serve_decode_tokens_total", qos_class, decode_tokens)
        self._count("serve_decode_steps_total", qos_class, decode_steps)
        ms_per_step = 1e3 * decode_s / max(1, decode_steps)
        self._observe("serve_ms_per_step", qos_class, ms_per_step,
                      LATENCY_MS_BUCKETS)
        if decode_s > 0:
            self._observe("serve_decode_tok_s", qos_class,
                          decode_tokens / decode_s, TOK_S_BUCKETS)
        if drift is not None:
            self._observe("serve_drift", qos_class, float(drift),
                          DRIFT_BUCKETS)
        self.events.append({
            "batch": batch,
            "tick": tick,
            "n_requests": n_requests,
            "prefill_s": round(prefill_s, 6),
            "decode_s": round(decode_s, 6),
            "prefill_tokens": prefill_tokens,
            "decode_tokens": decode_tokens,
            "prefill_tok_s": round(prefill_tokens / prefill_s, 2)
            if prefill_s > 0 else None,
            "decode_tok_s": round(decode_tokens / decode_s, 2)
            if decode_s > 0 else None,
            "ms_per_step": round(ms_per_step, 3),
            "plan": plan_id,
            "drift": None if drift is None else round(float(drift), 6),
            "backlog": backlog,
            "class": qos_class,
        })

    def record_step(self, *, step: int, tick: int, step_s: float,
                    by_class: dict, decode_tokens: int, prefill_tokens: int,
                    plan_id: str | None = None, drift: float | None = None,
                    backlog: int = 0, occupancy: float = 0.0) -> None:
        """One continuous-batching decode step.  ``by_class`` maps each
        QoS class with active rows to ``{"rows", "decode_tokens",
        "prefill_tokens"}``.  The full step time is attributed to *every*
        active class (it is the latency each one experienced) and to
        decode time in the aggregate — pessimistic for continuous mode,
        since prefill rows ride inside the same step, but that bias runs
        *against* the mode so a measured win is real."""
        step_ms = 1e3 * step_s
        self._count("serve_steps_total", None, 1)
        self._count("serve_decode_steps_total", None, 1)
        self._count("serve_decode_s_total", None, step_s)
        self._count("serve_decode_tokens_total", None, decode_tokens)
        self._count("serve_prefill_tokens_total", None, prefill_tokens)
        self._observe("serve_ms_per_step", None, step_ms, LATENCY_MS_BUCKETS)
        if decode_tokens and step_s > 0:
            self._observe("serve_decode_tok_s", None,
                          decode_tokens / step_s, TOK_S_BUCKETS)
        if drift is not None:
            self._observe("serve_drift", None, float(drift), DRIFT_BUCKETS)
        for cls, row in by_class.items():
            # class-label counters only — the ``_all`` aggregate was
            # counted once above; ``_count`` here would double it
            def inc(name: str, v: float) -> None:
                self.registry.counter(name, **{"class": cls}).inc(v)

            inc("serve_steps_total", 1)
            inc("serve_decode_steps_total", 1)
            inc("serve_decode_s_total", step_s)
            inc("serve_decode_tokens_total", row.get("decode_tokens", 0))
            inc("serve_prefill_tokens_total", row.get("prefill_tokens", 0))
            self.registry.histogram("serve_ms_per_step",
                                    buckets=LATENCY_MS_BUCKETS,
                                    **{"class": cls}).observe(step_ms)
            if drift is not None:
                self.registry.histogram("serve_drift",
                                        buckets=DRIFT_BUCKETS,
                                        **{"class": cls}).observe(float(drift))
        self.registry.gauge("serve_slot_occupancy",
                            **{"class": ALL_CLASSES}).set(occupancy)
        self.events.append({
            "step": step,
            "tick": tick,
            "step_ms": round(step_ms, 3),
            "active": {c: r.get("rows", 0) for c, r in by_class.items()},
            "decode_tokens": decode_tokens,
            "prefill_tokens": prefill_tokens,
            "plan": plan_id,
            "drift": None if drift is None else round(float(drift), 6),
            "backlog": backlog,
            "occupancy": round(occupancy, 3),
        })

    def record_costs(self, qos_class: str | None, tokens: int,
                     row: dict) -> None:
        """Attribute one step's decoded tokens to the live plan's cost
        row (:func:`repro.obs.costs.plan_cost_row`, cached per plan by
        the engine).  Exports the paper's dividend as counters:
        ``mlp_macs_total``/``approx_macs_total{class}`` and
        ``area_mac_saved_total{class,layer}`` (the guaranteed lower
        bound; ``area_mac_saved_hi_total`` carries the optimistic end of
        the bracket, see :mod:`repro.obs.costs`)."""
        if not tokens or row is None:
            return
        self._count("mlp_macs", qos_class, tokens * row["macs"])
        self._count("approx_macs", qos_class, tokens * row["approx_macs"])

        def saved(cls: str) -> None:
            self.registry.counter(
                "area_mac_saved",
                **{"class": cls, "layer": ALL_CLASSES}).inc(
                    tokens * row["saved_lo"])
            self.registry.counter(
                "area_mac_saved_hi",
                **{"class": cls, "layer": ALL_CLASSES}).inc(
                    tokens * row["saved_hi"])
            for layer, v in row["layers"].items():
                self.registry.counter(
                    "area_mac_saved",
                    **{"class": cls, "layer": layer}).inc(tokens * v)

        saved(ALL_CLASSES)
        if qos_class is not None:
            saved(qos_class)

    def record_pages(self, *, used: int, total: int) -> None:
        """Page-pool occupancy gauges (continuous engine, per step) —
        these ride the registry so the Prometheus text and trace-dir
        snapshots carry KV pressure, not just slot occupancy."""
        self.registry.gauge("serve_page_pool_used").set(used)
        self.registry.gauge("serve_page_pool_pages").set(total)
        self.registry.gauge("serve_page_pool_occupancy").set(
            used / total if total else 0.0)

    def record_ttft(self, qos_class: str | None, ttft_s: float) -> None:
        """Time-to-first-token for one request: admission (entering the
        engine's queue) to the step that produced its first generated
        token — queue wait, any preemption-induced suspension, and
        prefill all included.  The SLO users actually feel."""
        self._observe("serve_ttft_ms", qos_class, 1e3 * float(ttft_s),
                      TTFT_MS_BUCKETS)

    def record_request_done(self, qos_class: str | None) -> None:
        """One request fully decoded (the continuous engine's analog of
        ``record_batch``'s per-batch request count)."""
        self._count("serve_requests_total", qos_class, 1)

    def record_preemption(self, *, step: int, victim_rid: int,
                          victim_class: str | None,
                          by_class: str | None) -> None:
        """A running slot was preempted (its request keeps its pages and
        resumes later).  Counted against the *victim's* class."""
        self._count("serve_preemptions_total", victim_class, 1)
        self.events.append({
            "step": step, "preempted_rid": victim_rid,
            "victim_class": victim_class, "by_class": by_class,
        })

    def record_swap(self, *, batch: int, reason: str, old: str | None,
                    new: str | None) -> None:
        self.registry.counter("serve_swaps_total", reason=reason).inc()
        self.swaps.append({"batch": batch, "reason": reason,
                           "from": old, "to": new})

    def record_queue(self, qos_class: str | None, depth: int,
                     wait_s=()) -> None:
        """Queue health at admission time: current depth (gauge) plus
        each drained request's time-in-queue — both the legacy seconds
        histogram and a per-class queueing-delay ms histogram on SLO-
        scale buckets (the Prometheus series request timelines read)."""
        cls = qos_class if qos_class is not None else ALL_CLASSES
        self.registry.gauge("serve_queue_depth",
                            **{"class": cls}).set(depth)
        for w in wait_s:
            self._observe("serve_queue_wait_s", qos_class, float(w), None)
            self._observe("serve_queue_delay_ms", qos_class,
                          1e3 * float(w), WAIT_MS_BUCKETS)

    def record_suspension(self, qos_class: str | None,
                          suspended_s: float) -> None:
        """One preempted request resumed after ``suspended_s`` out of a
        slot — the per-class suspension-time histogram, charged (like
        the preemption counter) to the victim's class."""
        self._count("serve_resumes_total", qos_class, 1)
        self._observe("serve_suspension_ms", qos_class,
                      1e3 * float(suspended_s), WAIT_MS_BUCKETS)

    # ------------------------------------------------------------------- read
    @property
    def n_batches(self) -> int:
        return int(self._counter_value("serve_batches_total"))

    @property
    def n_requests(self) -> int:
        return int(self._counter_value("serve_requests_total"))

    @property
    def swap_count(self) -> int:
        return len(self.swaps)

    @property
    def preemptions(self) -> int:
        return int(self._counter_value("serve_preemptions_total"))

    def _class_names(self) -> list[str]:
        # union of both recording paths: fixed-batch serves label
        # serve_batches_total, continuous serves label serve_ms_per_step
        # per step — a class served either way gets its summary row
        return sorted({labels["class"]
                       for name in ("serve_batches_total",
                                    "serve_ms_per_step")
                       for labels, _ in self.registry.with_name(name)
                       if labels["class"] != ALL_CLASSES})

    def _class_row(self, cls: str) -> dict:
        decode_s = self._counter_value("serve_decode_s_total", cls)
        steps = self._counter_value("serve_decode_steps_total", cls)
        tokens = self._counter_value("serve_decode_tokens_total", cls)
        lat = self.registry.find("serve_ms_per_step", **{"class": cls})
        drift = self.registry.find("serve_drift", **{"class": cls})
        row = {
            "batches": int(self._counter_value("serve_batches_total", cls)),
            "requests": int(self._counter_value("serve_requests_total", cls)),
            "decode_tok_s": round(tokens / decode_s, 2) if decode_s else 0.0,
            "ms_per_step": round(1e3 * decode_s / steps, 3) if steps else 0.0,
            "mean_drift": round(drift.mean, 6)
            if drift is not None and drift.count else None,
            "max_drift": round(drift.max, 6)
            if drift is not None and drift.count else None,
            "drift_samples": drift.count if drift is not None else 0,
        }
        # the SLO-facing numbers a mean can't express: per-class latency
        # percentiles over the run's per-batch ms/step observations
        if lat is not None and lat.count:
            for p, v in lat.percentiles().items():
                row[f"{p}_ms_per_step"] = round(v, 3)
        ttft = self.registry.find("serve_ttft_ms", **{"class": cls})
        if ttft is not None and ttft.count:
            for p, v in ttft.percentiles().items():
                row[f"{p}_ttft_ms"] = round(v, 3)
        pre = self._counter_value("serve_preemptions_total", cls)
        if pre:
            row["preemptions"] = int(pre)
        costs = self._cost_block(cls)
        if costs is not None:
            row["costs"] = costs
        return row

    def summary(self) -> dict:
        """The aggregates the CI bench row wants: throughput, latency
        (mean *and* p50/p95/p99), swap activity.  Rates come from the
        whole-run registry counters, not the ring, so they stay
        consistent with ``batches``/``requests`` even after the ring
        wraps on long serves."""
        reasons: dict[str, int] = {}
        for s in self.swaps:
            reasons[s["reason"]] = reasons.get(s["reason"], 0) + 1
        decode_s = self._counter_value("serve_decode_s_total")
        prefill_s = self._counter_value("serve_prefill_s_total")
        steps = self._counter_value("serve_decode_steps_total")
        lat = self.registry.find("serve_ms_per_step",
                                 **{"class": ALL_CLASSES})
        out = {
            "batches": self.n_batches,
            "requests": self.n_requests,
            "wall_s": round(time.time() - self._t0, 3),
            "decode_tok_s": round(
                self._counter_value("serve_decode_tokens_total") / decode_s,
                2) if decode_s else 0.0,
            "prefill_tok_s": round(
                self._counter_value("serve_prefill_tokens_total") / prefill_s,
                2) if prefill_s else 0.0,
            "ms_per_step": round(1e3 * decode_s / steps, 3) if steps else 0.0,
            "swaps": self.swap_count,
            "swaps_by_reason": reasons,
            "plans_used": len(self.plans),
        }
        if lat is not None and lat.count:
            out["latency_ms_per_step"] = {
                p: round(v, 3) for p, v in lat.percentiles().items()}
        tok = self.registry.find("serve_decode_tok_s",
                                 **{"class": ALL_CLASSES})
        if tok is not None and tok.count:
            # per-observation throughput percentiles: the totals-based
            # decode_tok_s above folds the one-off trace/compile step into
            # the rate; the median does not, so paired engine comparisons
            # read steady-state throughput here
            out["decode_tok_s_pct"] = {
                p: round(v, 2) for p, v in tok.percentiles().items()}
        steps = self._counter_value("serve_steps_total")
        if steps:
            out["steps"] = int(steps)
        if self.preemptions:
            out["preemptions"] = self.preemptions
        ttft = self.registry.find("serve_ttft_ms", **{"class": ALL_CLASSES})
        if ttft is not None and ttft.count:
            out["ttft_ms"] = {
                p: round(v, 3) for p, v in ttft.percentiles().items()}
        costs = self._cost_block()
        if costs is not None:
            out["costs"] = costs
        classes = {cls: self._class_row(cls) for cls in self._class_names()}
        if classes:
            out["classes"] = classes
        return out

    def dump(self, path: str | Path) -> dict:
        """Write the full telemetry document (summary + plan table + swap
        log + ring events) as JSON — atomically, creating parent dirs —
        and return it."""
        doc = {
            "summary": self.summary(),
            "plans": self.plans,
            "swaps": self.swaps,
            "events": list(self.events),
        }
        write_bench_json(Path(path), doc)
        return doc
