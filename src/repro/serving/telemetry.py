"""Ring-buffer serving telemetry: per-batch metrics, plan table, swaps.

The engine appends one event per served batch (tok/s split into prefill
and decode, ms/step, active plan id, measured shadow drift when sampled)
into a bounded ring — a long-running server never grows the log without
bound — while the *plan table* (plan id -> per-layer operator keys) and
the *swap log* are tiny and kept whole.  ``dump()`` writes everything as
one JSON document; ``summary()`` is the aggregate the bench trajectory
ingests (``BENCH_serve.json``).
"""

from __future__ import annotations

import json
import time
from collections import deque
from pathlib import Path

__all__ = ["Telemetry"]


class Telemetry:
    def __init__(self, capacity: int = 4096) -> None:
        self.capacity = int(capacity)
        self.events: deque[dict] = deque(maxlen=self.capacity)
        self.plans: dict[str, dict] = {}
        self.swaps: list[dict] = []
        self.n_batches = 0
        self.n_requests = 0
        # whole-run accumulators: the ring may wrap on long serves, but the
        # summary's rates must cover the same window as its counters
        self._prefill_s = 0.0
        self._decode_s = 0.0
        self._prefill_tokens = 0
        self._decode_tokens = 0
        self._decode_steps = 0
        # per-QoS-class accumulators (class-aware serving); keys appear as
        # classes are actually served, so a single-tier serve stays clean
        self._classes: dict[str, dict] = {}
        self._t0 = time.time()

    # ------------------------------------------------------------------ write
    def register_plan(self, plan) -> str:
        """Record a :class:`~repro.library.qos.LayerPlan`'s identity once;
        batch events reference the short ``plan_id``."""
        pid = plan.plan_id
        if pid not in self.plans:
            self.plans[pid] = {
                "layers": [c.key or "exact" for c in plan.choices],
                "total_area": plan.total_area,
                "area_saving": plan.area_saving,
                "predicted_drift": plan.predicted_total,
                "budget": plan.budget,
            }
        return pid

    def record_batch(self, *, batch: int, tick: int, n_requests: int,
                     prefill_s: float, decode_s: float, prefill_tokens: int,
                     decode_tokens: int, decode_steps: int,
                     plan_id: str | None, drift: float | None = None,
                     backlog: int = 0, qos_class: str | None = None) -> None:
        self.n_batches += 1
        self.n_requests += n_requests
        self._prefill_s += prefill_s
        self._decode_s += decode_s
        self._prefill_tokens += prefill_tokens
        self._decode_tokens += decode_tokens
        self._decode_steps += decode_steps
        if qos_class is not None:
            c = self._classes.setdefault(qos_class, {
                "batches": 0, "requests": 0, "decode_s": 0.0,
                "decode_steps": 0, "decode_tokens": 0,
                "drift_sum": 0.0, "drift_n": 0, "drift_max": 0.0,
            })
            c["batches"] += 1
            c["requests"] += n_requests
            c["decode_s"] += decode_s
            c["decode_steps"] += decode_steps
            c["decode_tokens"] += decode_tokens
            if drift is not None:
                c["drift_sum"] += float(drift)
                c["drift_n"] += 1
                c["drift_max"] = max(c["drift_max"], float(drift))
        self.events.append({
            "batch": batch,
            "tick": tick,
            "n_requests": n_requests,
            "prefill_s": round(prefill_s, 6),
            "decode_s": round(decode_s, 6),
            "prefill_tokens": prefill_tokens,
            "decode_tokens": decode_tokens,
            "prefill_tok_s": round(prefill_tokens / prefill_s, 2)
            if prefill_s > 0 else None,
            "decode_tok_s": round(decode_tokens / decode_s, 2)
            if decode_s > 0 else None,
            "ms_per_step": round(1e3 * decode_s / max(1, decode_steps), 3),
            "plan": plan_id,
            "drift": None if drift is None else round(float(drift), 6),
            "backlog": backlog,
            "class": qos_class,
        })

    def record_swap(self, *, batch: int, reason: str, old: str | None,
                    new: str | None) -> None:
        self.swaps.append({"batch": batch, "reason": reason,
                           "from": old, "to": new})

    # ------------------------------------------------------------------- read
    @property
    def swap_count(self) -> int:
        return len(self.swaps)

    def summary(self) -> dict:
        """The aggregates the CI bench row wants: throughput, latency,
        swap activity.  Rates come from whole-run accumulators, not the
        ring, so they stay consistent with ``batches``/``requests`` even
        after the ring wraps on long serves."""
        reasons: dict[str, int] = {}
        for s in self.swaps:
            reasons[s["reason"]] = reasons.get(s["reason"], 0) + 1
        classes = {}
        for name, c in self._classes.items():
            classes[name] = {
                "batches": c["batches"],
                "requests": c["requests"],
                "decode_tok_s": round(c["decode_tokens"] / c["decode_s"], 2)
                if c["decode_s"] else 0.0,
                "ms_per_step": round(1e3 * c["decode_s"] /
                                     c["decode_steps"], 3)
                if c["decode_steps"] else 0.0,
                "mean_drift": round(c["drift_sum"] / c["drift_n"], 6)
                if c["drift_n"] else None,
                "max_drift": round(c["drift_max"], 6)
                if c["drift_n"] else None,
                "drift_samples": c["drift_n"],
            }
        return {
            "batches": self.n_batches,
            "requests": self.n_requests,
            "wall_s": round(time.time() - self._t0, 3),
            "decode_tok_s": round(self._decode_tokens / self._decode_s, 2)
            if self._decode_s else 0.0,
            "prefill_tok_s": round(self._prefill_tokens / self._prefill_s, 2)
            if self._prefill_s else 0.0,
            "ms_per_step": round(1e3 * self._decode_s /
                                 self._decode_steps, 3)
            if self._decode_steps else 0.0,
            "swaps": self.swap_count,
            "swaps_by_reason": reasons,
            "plans_used": len(self.plans),
            **({"classes": classes} if classes else {}),
        }

    def dump(self, path: str | Path) -> dict:
        """Write the full telemetry document (summary + plan table + swap
        log + ring events) as JSON and return it."""
        doc = {
            "summary": self.summary(),
            "plans": self.plans,
            "swaps": self.swaps,
            "events": list(self.events),
        }
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(doc, indent=1, sort_keys=True))
        return doc
