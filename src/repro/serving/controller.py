"""QoS controller: walk the operator frontier between batches.

The controller owns a :class:`PlanLadder` — a monotone sequence of QoS
plans from "most exact" (level 0) down to "full greedy descent" (last
level), built once from the frontier via :func:`repro.library.qos.plan_ladder`
and rebuilt on library refreshes.  Between batches it observes an EWMA of
per-step decode latency plus the *measured* logit drift against an exact
shadow step (sampled every ``shadow_every`` batches) and decides whether
to move one level:

* **up** (cheaper operators) when smoothed latency sits above the target
  band *and* measured drift leaves headroom under the budget;
* **down** (more exact) when measured drift eats into the budget — drift
  pressure beats load pressure — or when latency sits comfortably below
  the band, so idle capacity buys accuracy back.

Moves need ``patience`` consecutive out-of-band observations and are
followed by ``cooldown`` quiet batches; inside the deadband both streaks
reset.  Together these are the hysteresis that keeps an oscillating load
from flapping plans (pinned by ``tests/test_serving.py``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from ..library.qos import LayerPlan, plan_ladder, stack_luts

__all__ = ["ControllerConfig", "PlanLadder", "QoSController",
           "effective_load_ms"]


def effective_load_ms(raw_ms: float, *, backlog: int = 0, capacity: int = 1,
                      occupancy: float | None = None) -> float:
    """The Little's-law-flavoured load signal the controller observes.

    Raw step latency is nearly plan-independent, so outstanding work is
    what says "trade accuracy for throughput".  The fixed-batch loop
    (``occupancy=None``) scales service time by whole-queue backlog:
    ``raw * (1 + backlog / capacity)``.  Under continuous batching that
    double-counts — most "backlog" is requests *already being served* —
    so the signal becomes slot occupancy plus true admission-queue
    depth: ``raw * (occupancy + backlog / capacity)``, where ``backlog``
    counts only requests still waiting for a slot.  An idle continuous
    pool therefore reports near-zero load instead of its raw step time,
    and a full pool with an empty queue reports exactly ``raw``."""
    cap = max(1, int(capacity))
    if occupancy is None:
        return raw_ms * (1.0 + backlog / cap)
    return raw_ms * (float(occupancy) + backlog / cap)


@dataclass(frozen=True)
class ControllerConfig:
    target_ms_per_step: float = 50.0   # latency target the EWMA is held to
    drift_budget: float = 0.05         # mean |Δlogit| allowed vs exact shadow
    ewma_alpha: float = 0.4            # smoothing for latency and drift
    deadband: float = 0.15             # +/- fraction around the target: no-op
    patience: int = 2                  # consecutive out-of-band obs to move
    cooldown: int = 2                  # quiet batches after any move
    shadow_every: int = 4              # shadow-drift sampling period (batches)
    drift_headroom: float = 0.7        # may only move up while
    #                                    ewma_drift <= headroom * budget

    def __post_init__(self) -> None:
        assert self.target_ms_per_step > 0 and self.patience >= 1
        assert 0 < self.ewma_alpha <= 1 and 0 <= self.deadband < 1


class PlanLadder:
    """The frontier materialized as swap-ready levels.

    Holds the compiled operator list the plans index into, and caches each
    level's stacked LUT array(s) so a swap re-stacks nothing.  ``stacker``
    overrides how a plan materializes — the mixed-width ladder
    (:func:`repro.precision.plans.build_mixed_ladder`) stacks one array
    per width group instead of a single ``(L, side, side)`` array.
    """

    def __init__(self, compiled, plans: Sequence[LayerPlan],
                 exact_area: float, sensitivities: np.ndarray,
                 requested_levels: int | None = None, *,
                 stacker=None) -> None:
        assert plans, "ladder needs at least the all-exact plan"
        self.compiled = list(compiled)
        self.plans = list(plans)
        self.exact_area = float(exact_area)
        self.sensitivities = np.asarray(sensitivities, dtype=np.float64)
        # a sparse frontier may dedup below the requested resolution; keep
        # the request so a refresh against a denser frontier regains it
        self.requested_levels = (len(self.plans) if requested_levels is None
                                 else int(requested_levels))
        self._stacker = stacker
        self._stacks: dict[int, object] = {}

    @classmethod
    def build(cls, compiled, n_layers: int, *, exact_area: float,
              sensitivities: Sequence[float] | np.ndarray | None = None,
              levels: int = 6) -> "PlanLadder":
        sens = (np.ones(n_layers) if sensitivities is None
                else np.asarray(sensitivities, dtype=np.float64))
        plans = plan_ladder(compiled, sens, exact_area=exact_area,
                            levels=levels)
        return cls(compiled, plans, exact_area, sens, requested_levels=levels)

    def __len__(self) -> int:
        return len(self.plans)

    def plan(self, level: int) -> LayerPlan:
        return self.plans[level]

    def luts(self, level: int):
        stack = self._stacks.get(level)
        if stack is None:
            if self._stacker is not None:
                stack = self._stacker(self.plans[level])
            else:
                stack = stack_luts(self.plans[level], self.compiled)
            self._stacks[level] = stack
        return stack

    def refresh(self, compiled, exact_area: float,
                sensitivities=None) -> "PlanLadder":
        """Rebuild against a refreshed frontier, keeping the sensitivity
        model and the *originally requested* resolution — the watcher
        path (a denser frontier may now fill levels a sparse one
        couldn't).  A ladder built on a measured ``(L, O)`` cost matrix
        must be handed a re-priced ``sensitivities`` for the new frontier
        (the serving engine derives one from its sensitivity profile);
        the stale matrix would not line up with the refreshed operator
        columns.  Mixed-width ladders refresh through
        :func:`repro.precision.plans.build_mixed_ladder` instead (the
        frozen width map and operator masks are not representable here)."""
        assert self._stacker is None, (
            "custom-stacked (mixed-width) ladders refresh via "
            "precision.plans.build_mixed_ladder, not PlanLadder.refresh"
        )
        sens = self.sensitivities if sensitivities is None else sensitivities
        return PlanLadder.build(
            compiled, len(sens), exact_area=exact_area,
            sensitivities=sens, levels=self.requested_levels,
        )


class QoSController:
    def __init__(self, ladder: PlanLadder, config: ControllerConfig,
                 *, level: int = 0) -> None:
        self.ladder = ladder
        self.config = config
        self.level = min(level, len(ladder) - 1)
        self.ewma_ms: float | None = None
        self.ewma_drift = 0.0
        self._hot = 0          # consecutive obs above the band
        self._cool = 0         # consecutive obs below the band
        self._over = 0         # consecutive obs over the drift budget
        self._quiet = 0        # cooldown countdown
        self.moves = 0
        self.last_reason: str | None = None

    # ------------------------------------------------------------------ state
    @property
    def plan(self) -> LayerPlan:
        return self.ladder.plan(self.level)

    def luts(self) -> np.ndarray:
        return self.ladder.luts(self.level)

    def wants_shadow(self, batch_idx: int) -> bool:
        """Should the engine sample an exact shadow step this batch?"""
        return (self.config.drift_budget > 0
                and batch_idx % max(1, self.config.shadow_every) == 0)

    # ---------------------------------------------------------------- control
    def observe(self, ms_per_step: float, drift: float | None = None
                ) -> int | None:
        """Feed one batch's measurements; returns the new level when the
        controller decides to move, else ``None``."""
        a = self.config.ewma_alpha
        self.ewma_ms = (ms_per_step if self.ewma_ms is None
                        else a * ms_per_step + (1 - a) * self.ewma_ms)
        if drift is not None:
            self.ewma_drift = a * float(drift) + (1 - a) * self.ewma_drift

        if self._quiet > 0:
            self._quiet -= 1
            return None

        hi = self.config.target_ms_per_step * (1 + self.config.deadband)
        lo = self.config.target_ms_per_step * (1 - self.config.deadband)
        if self.ewma_drift > self.config.drift_budget:
            self._over += 1
        else:
            self._over = 0
        if self.ewma_ms > hi:
            self._hot, self._cool = self._hot + 1, 0
        elif self.ewma_ms < lo:
            self._hot, self._cool = 0, self._cool + 1
        else:
            self._hot = self._cool = 0   # deadband: hysteresis resets streaks

        p = self.config.patience
        headroom = (self.ewma_drift
                    <= self.config.drift_headroom * self.config.drift_budget)
        if self._over >= p and self.level > 0:
            return self._move(-1, "drift")           # accuracy first
        if self._hot >= p and headroom and self.level < len(self.ladder) - 1:
            return self._move(+1, "load")
        if self._cool >= p and self.level > 0:
            return self._move(-1, "idle")
        return None

    def _move(self, delta: int, reason: str) -> int:
        self.level += delta
        self._hot = self._cool = self._over = 0
        self._quiet = self.config.cooldown
        self.moves += 1
        self.last_reason = reason
        return self.level

    def adopt(self, ladder: PlanLadder, *, level: int | None = None) -> None:
        """Switch to an already-built ladder, clamping the level.  The
        level index is preserved (the ladder's budget grid shifts with the
        frontier, but relative position on it is the controller's
        operating point)."""
        self.ladder = ladder
        self.level = min(self.level if level is None else level,
                         len(ladder) - 1)

    def refresh(self, compiled, exact_area: float) -> None:
        """Rebuild the ladder against a refreshed frontier and adopt it.
        The serving engine's watcher path instead builds first and adopts
        only after the new stack validated (see
        :meth:`repro.serving.engine.ServingEngine.refresh_library`)."""
        self.adopt(self.ladder.refresh(compiled, exact_area))
