"""Offline per-layer sensitivity profiling: measure, persist, reuse.

The QoS planner is only as good as its drift model, and until now every
consumer re-derived that model ad hoc — ``examples/approx_inference.py``
carried its own drift-matrix loop, the serve CLI fell back to uniform
sensitivities.  This module is the single measured code path:

* a **probe** (:func:`truncation_probe`) is a deterministic synthetic
  approximate table — the exact product table with its low bits dropped —
  so profiling needs no operator library and two runs of the profiler
  produce bit-identical profiles;
* :func:`model_eval_drift` builds the one jitted forward evaluator every
  measurement routes through (per-layer table overrides vs the all-exact
  baseline *at the same width*, so the measured number is pure LUT
  approximation drift);
* :func:`measure_profile` probes one layer at a time at every serving
  width and emits a :class:`SensitivityProfile` — per-width, per-layer
  drift per unit compiled-table mae — persisted as JSON next to the
  operator library (``<library>/_profiles/<model>.json``);
* with a library at hand, :func:`measure_cost_matrix` measures the full
  per-(layer, operator) drift matrix for a width's frontier; the profile
  stores it keyed by operator content keys so plan construction can price
  *known* operators by measurement and fall back to the linear model only
  for operators a background fleet sweep adds later
  (:func:`costs_for`).

CLI (writes the profile the serve launcher's ``--profile`` consumes)::

    python -m repro.sensitivity.profile --arch gemma3-1b --reduced \
        --library runs/lib --out runs/lib/_profiles/gemma3-1b.json
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from ..precision.widths import SUPPORTED_WIDTHS, exact_table, get_width

__all__ = [
    "Probe",
    "truncation_probe",
    "SensitivityProfile",
    "model_eval_drift",
    "measure_profile",
    "measure_cost_matrix",
    "costs_for",
    "default_profile_path",
    "load_profile",
]

PROFILE_FORMAT = 1


@dataclass(frozen=True)
class Probe:
    """A synthetic approximate operator used to excite one layer at a
    time.  Duck-types the slice of ``CompiledLut`` the qos measurement
    helpers read (``lut`` + ``mae16``)."""

    lut: np.ndarray          # (side, side) int32
    mae: float
    bits: int
    drop: int

    @property
    def mae16(self) -> float:   # CompiledLut-compatible spelling
        return self.mae


def truncation_probe(bits: int, drop: int | None = None) -> Probe:
    """The exact ``bits``-bit product table with the low ``drop`` bits
    zeroed (default: the low half of the product).  Deterministic, well
    above numerical noise, and library-independent — the probe is pure
    arithmetic, so a profile never depends on what a store happens to
    hold."""
    w = get_width(bits)
    drop = bits if drop is None else int(drop)
    exact = exact_table("mul", bits)
    lut = (exact >> drop) << drop
    mae = float(np.abs(lut - exact).mean())
    assert mae > 0, "probe must be approximate"
    return Probe(lut=lut.astype(np.int32), mae=mae, bits=w.bits, drop=drop)


@dataclass
class SensitivityProfile:
    """Measured per-layer drift sensitivities of one model, per width.

    ``sens[bits][l]`` is layer ``l``'s measured drift per unit
    compiled-table mae at serving width ``bits`` (the linear model the
    QoS planner prices unknown operators with).  ``costs[bits]`` is an
    optional measured per-(layer, operator) drift matrix over a concrete
    frontier, keyed by operator content keys — exact prices for the
    operators that existed at profiling time.
    """

    model: str
    n_layers: int
    sens: dict[int, np.ndarray]                 # bits -> (L,)
    probe_mae: dict[int, float] = field(default_factory=dict)
    costs: dict[int, tuple[list[str], np.ndarray]] = field(
        default_factory=dict)                   # bits -> (keys, (L, O))
    meta: dict = field(default_factory=dict)

    @property
    def widths(self) -> tuple[int, ...]:
        return tuple(sorted(self.sens))

    def sensitivities(self, bits: int) -> np.ndarray:
        b = int(bits)
        if b not in self.sens:
            raise KeyError(
                f"profile of {self.model!r} was not measured at width {b} "
                f"(profiled widths: {self.widths}); re-run "
                f"python -m repro.sensitivity.profile with --widths "
                f"covering the serving width")
        return np.asarray(self.sens[b], dtype=np.float64).copy()

    # ------------------------------------------------------------- persist
    def to_doc(self) -> dict:
        return {
            "format_version": PROFILE_FORMAT,
            "model": self.model,
            "n_layers": self.n_layers,
            "sens": {str(b): np.asarray(s).tolist()
                     for b, s in self.sens.items()},
            "probe_mae": {str(b): m for b, m in self.probe_mae.items()},
            "costs": {str(b): {"keys": list(keys),
                               "matrix": np.asarray(m).tolist()}
                      for b, (keys, m) in self.costs.items()},
            "meta": self.meta,
        }

    def save(self, path) -> Path:
        from ..library.store import atomic_write_json

        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        atomic_write_json(p, self.to_doc())
        return p

    @classmethod
    def from_doc(cls, doc: dict) -> "SensitivityProfile":
        return cls(
            model=doc["model"],
            n_layers=int(doc["n_layers"]),
            sens={int(b): np.asarray(s, dtype=np.float64)
                  for b, s in doc["sens"].items()},
            probe_mae={int(b): float(m)
                       for b, m in doc.get("probe_mae", {}).items()},
            costs={int(b): (list(d["keys"]),
                            np.asarray(d["matrix"], dtype=np.float64))
                   for b, d in doc.get("costs", {}).items()},
            meta=doc.get("meta", {}),
        )


def load_profile(path) -> SensitivityProfile:
    return SensitivityProfile.from_doc(json.loads(Path(path).read_text()))


def default_profile_path(library, model: str) -> Path:
    """Where a profile lives relative to the operator library it was
    measured next to."""
    return Path(library) / "_profiles" / f"{model}.json"


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------
def model_eval_drift(cfg, params, batch, bits: int):
    """The one measured-drift evaluator: returns ``eval_drift(per_layer)``
    where ``per_layer[l]`` is layer ``l``'s ``(side, side)`` table
    (``None`` = exact), evaluated as mean |Δlogit| against the all-exact
    baseline at width ``bits``.  One jitted forward serves the baseline
    and every probe (the per-layer stack is a plain argument)."""
    import jax
    import jax.numpy as jnp

    from ..models import forward_fn

    assert cfg.approx_mlp, (
        "profiling routes MLP matmuls through LUTs; build the config with "
        ".with_approx_mlp()"
    )
    fwd = forward_fn(cfg)
    fwd_j = jax.jit(lambda p, b, lut: fwd(cfg, p, b, lut=lut)[0])
    w = get_width(bits)
    exact = exact_table("mul", bits).astype(np.int32)
    base_stack = np.broadcast_to(
        exact, (cfg.n_layers, w.side, w.side)).copy()
    base = fwd_j(params, batch, jnp.asarray(base_stack))

    def eval_drift(per_layer) -> float:
        stack = np.stack([exact if t is None else np.asarray(t, np.int32)
                          for t in per_layer])
        out = fwd_j(params, batch, jnp.asarray(stack))
        return float(jnp.abs(out - base).mean())

    return eval_drift


def measure_profile(cfg, params, batch, *, widths=SUPPORTED_WIDTHS,
                    drop: int | None = None,
                    library=None, meta: dict | None = None
                    ) -> SensitivityProfile:
    """Probe one layer at a time at every serving width and (optionally,
    with a library) measure the full per-(layer, operator) cost matrix of
    each width's frontier.  Deterministic for fixed (cfg, params, batch).
    """
    from ..library.qos import measure_layer_costs, measure_sensitivities

    sens: dict[int, np.ndarray] = {}
    probe_mae: dict[int, float] = {}
    costs: dict[int, tuple[list[str], np.ndarray]] = {}
    for bits in sorted(int(b) for b in widths):
        probe = truncation_probe(bits, drop)
        ev = model_eval_drift(cfg, params, batch, bits)
        sens[bits] = measure_sensitivities(ev, cfg.n_layers, probe)
        probe_mae[bits] = probe.mae
        if library is not None:
            from ..precision.plans import load_frontier

            compiled, _, _ = load_frontier(library, bits)
            matrix = measure_layer_costs(ev, cfg.n_layers, compiled)
            costs[bits] = ([rec.key for rec, _ in compiled], matrix)
    return SensitivityProfile(
        model=cfg.name, n_layers=cfg.n_layers, sens=sens,
        probe_mae=probe_mae, costs=costs, meta=dict(meta or {}),
    )


def measure_cost_matrix(cfg, params, batch, compiled,
                        bits: int | None = None) -> np.ndarray:
    """Measured ``(L, O)`` drift matrix for one width's frontier — the
    code path ``examples/approx_inference.py`` routes through (it used to
    carry its own copy of this loop)."""
    from ..library.qos import measure_layer_costs

    if bits is None:
        sides = {comp.lut.shape[-1] for _, comp in compiled}
        assert len(sides) == 1, f"frontier mixes LUT sides {sorted(sides)}"
        bits = sides.pop().bit_length() - 1
    ev = model_eval_drift(cfg, params, batch, bits)
    return measure_layer_costs(ev, cfg.n_layers, compiled)


def costs_for(profile: SensitivityProfile | None, bits: int, compiled,
              n_layers: int) -> np.ndarray:
    """The ``(L, O)`` cost matrix a plan/ladder build should use for one
    width's frontier: measured columns where the profile covered the
    operator, the profile's linear model otherwise, uniform sensitivities
    when there is no profile at all.  This is what lets a measured plan
    keep pricing operators a fleet sweep lands *after* profiling."""
    maes = np.array([comp.mae for _, comp in compiled])
    if profile is None:
        return np.ones(n_layers)[:, None] * maes[None, :]
    assert profile.n_layers == n_layers, (
        f"profile measured {profile.n_layers} layers, model has {n_layers}")
    sens = profile.sensitivities(bits)
    out = sens[:, None] * maes[None, :]
    measured = profile.costs.get(int(bits))
    if measured is not None:
        keys, matrix = measured
        col = {k: i for i, k in enumerate(keys)}
        for o, (rec, _) in enumerate(compiled):
            i = col.get(rec.key)
            if i is not None:
                out[:, o] = matrix[:, i]
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def main(argv=None) -> None:
    import argparse

    import jax

    from ..configs import get_config
    from ..models import init_model

    ap = argparse.ArgumentParser(
        description="measure a per-layer sensitivity profile")
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--widths", default="4,8",
                    help="comma-separated serving widths to profile")
    ap.add_argument("--library", default=None,
                    help="operator store; also measures the per-(layer, "
                         "operator) cost matrix of each width's frontier")
    ap.add_argument("--out", default=None,
                    help="profile JSON path (default: "
                         "<library>/_profiles/<arch>.json)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    out = args.out
    if out is None:
        if args.library is None:
            raise SystemExit("--out is required without --library")
        cfg_name = get_config(args.arch, reduced=args.reduced).name
        out = default_profile_path(args.library, cfg_name)

    cfg = get_config(args.arch, reduced=args.reduced).with_approx_mlp()
    key = jax.random.PRNGKey(args.seed)
    params = init_model(cfg, key)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.seq), 0, cfg.vocab_size)}
    widths = tuple(int(b) for b in args.widths.split(","))
    profile = measure_profile(
        cfg, params, batch, widths=widths, library=args.library,
        meta={"arch": args.arch, "reduced": bool(args.reduced),
              "seed": args.seed, "batch": args.batch, "seq": args.seq},
    )
    path = profile.save(out)

    from ..launch.analysis import sensitivity_report

    print(sensitivity_report(profile))
    print(f"profile -> {path}")


if __name__ == "__main__":
    main()
