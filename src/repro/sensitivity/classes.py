"""Per-request QoS classes: named traffic tiers with their own drift
budgets.

One serve, several service levels: a ``gold`` request decodes on a more
exact plan than ``batch`` traffic in the same process, against the same
ladder, with no extra traces (per-class plans share the ladder's stack
shapes, so the class a batch serves under is just which stack rides the
jitted decode step's LUT argument).

The scheduler's contract is **isolation**: a class's effective level
depends only on (a) the shared load-driven global level and (b) that
class's *own* budget cap and measured-drift backoff.  Tightening
``batch``'s budget can therefore never worsen ``gold``'s drift — the
invariant ``tests/test_sensitivity.py`` pins down.

* :class:`QoSClass` / :class:`ClassBook` — the declared tiers, parsed
  from a CLI spec like ``gold:0.02,std:0.05,batch:0.2`` (listed order is
  drain priority).  A tier may additionally declare a p95 ms-per-step
  latency SLO — ``gold:0.02@8ms`` — which is what entitles its arrivals
  to *preempt* lower tiers in the continuous-batching engine
  (:mod:`repro.serving.slots`).
* :class:`ClassScheduler` — per-class level resolution over a
  :class:`~repro.serving.controller.PlanLadder`: a *cap* (the deepest
  level whose predicted drift fits the class budget) plus a measured
  backoff (a class whose shadow-measured EWMA drift overruns its budget
  tightens itself one level; sustained headroom relaxes it back).
* :func:`parse_class_mix` — the loadgen side: ``gold:0.1,std:0.6,...``
  arrival fractions.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from ..obs.metrics import MetricRegistry, get_registry

__all__ = [
    "QoSClass",
    "ClassBook",
    "ClassScheduler",
    "parse_class_mix",
]


@dataclass(frozen=True)
class QoSClass:
    """One traffic tier: its name, drift budget (mean |Δlogit| vs the
    exact shadow step), drain priority (lower drains first), and an
    optional p95 ms-per-step latency SLO.  A declared ``slo_ms`` is a
    *contract*, not a hint: under continuous batching it entitles this
    tier's arrivals to preempt running lower-tier slots."""

    name: str
    drift_budget: float
    priority: int = 0
    slo_ms: float | None = None

    def __post_init__(self) -> None:
        # ValueError (not assert): these come straight from CLI specs and
        # must fail loudly even under `python -O`
        if not self.name:
            raise ValueError("a QoS class needs a name")
        if self.drift_budget < 0:
            raise ValueError(
                f"class {self.name!r} has negative drift budget "
                f"{self.drift_budget}")
        if self.slo_ms is not None and self.slo_ms <= 0:
            raise ValueError(
                f"class {self.name!r} has non-positive latency SLO "
                f"{self.slo_ms} ms")


class ClassBook:
    """The declared tiers of one serve, in drain-priority order."""

    def __init__(self, classes: Sequence[QoSClass]) -> None:
        if not classes:
            raise ValueError("a class book declares at least one tier")
        ordered = sorted(classes, key=lambda c: c.priority)
        names = [c.name for c in ordered]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate class names {names}")
        self.classes: tuple[QoSClass, ...] = tuple(ordered)
        self._by_name = {c.name: c for c in ordered}

    @classmethod
    def parse(cls, spec: str) -> "ClassBook":
        """``"gold:0.02@8ms,std:0.05,batch:0.2"`` — listed order is
        priority; an optional ``@<p95>ms`` suffix declares the tier's
        per-step latency SLO (the ``ms`` unit tag itself is optional)."""
        classes = []
        for i, part in enumerate(p for p in spec.split(",") if p.strip()):
            try:
                body, _, slo = part.partition("@")
                name, budget = body.split(":")
                budget = float(budget)
                slo_ms = (float(slo.strip().removesuffix("ms"))
                          if slo.strip() else None)
            except ValueError:
                raise ValueError(
                    f"bad class spec {part!r} in {spec!r}; expected "
                    f"name:drift_budget[@p95ms][,...] "
                    f"(e.g. gold:0.02@8ms,batch:0.2)") from None
            classes.append(QoSClass(name.strip(), budget, priority=i,
                                    slo_ms=slo_ms))
        return cls(classes)

    def __len__(self) -> int:
        return len(self.classes)

    def __iter__(self):
        return iter(self.classes)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(c.name for c in self.classes)

    def get(self, name: str) -> QoSClass:
        return self._by_name[name]

    def route(self, name: str) -> str:
        """Map a request's class tag to a declared tier; unknown tags ride
        the lowest-priority tier (best effort, never dropped)."""
        return name if name in self._by_name else self.classes[-1].name

    def equal_mix(self) -> tuple[tuple[str, float], ...]:
        f = 1.0 / len(self.classes)
        return tuple((c.name, f) for c in self.classes)

    def drain_weights(self) -> dict[str, int]:
        """Default weighted-fair drain shares: each tier gets twice the
        next one's (``2^(n-1-i)`` in priority order), so ``gold`` still
        dominates but ``batch`` is never starved the way a strict
        priority drain starves it under sustained high-tier load."""
        n = len(self.classes)
        return {c.name: 1 << (n - 1 - i)
                for i, c in enumerate(self.classes)}


def parse_class_mix(spec: str) -> tuple[tuple[str, float], ...]:
    """``"gold:0.1,std:0.6,batch:0.3"`` -> normalized arrival fractions
    for :class:`repro.serving.loadgen.LoadProfile.class_mix`."""
    pairs = []
    for part in (p for p in spec.split(",") if p.strip()):
        try:
            name, frac = part.split(":")
            pairs.append((name.strip(), float(frac)))
        except ValueError:
            raise ValueError(
                f"bad class-mix entry {part!r} in {spec!r}; expected "
                f"name:fraction[,name:fraction...]") from None
    if not pairs:
        raise ValueError(f"empty class mix {spec!r}")
    if any(f < 0 for _, f in pairs):
        raise ValueError(f"class mix {spec!r} has a negative fraction")
    total = sum(f for _, f in pairs)
    if total <= 0:
        raise ValueError(f"class mix {spec!r} sums to 0")
    return tuple((n, f / total) for n, f in pairs)


class ClassScheduler:
    """Resolve each class's serving level over a plan ladder.

    ``level_for(name, global_level)`` = ``min(global level, class cap)``
    where the cap is the deepest ladder level whose *predicted* drift fits
    the class budget, minus the class's own measured backoff.  All state
    is per-class; nothing one class observes moves another (isolation).
    """

    def __init__(self, book: ClassBook, ladder, *, ewma_alpha: float = 0.4,
                 shadow_every: int = 4, headroom: float = 0.5,
                 relax_patience: int = 4,
                 registry: MetricRegistry | None = None) -> None:
        assert 0 < ewma_alpha <= 1 and 0 <= headroom < 1
        self.book = book
        self.ewma_alpha = float(ewma_alpha)
        self.shadow_every = max(1, int(shadow_every))
        self.headroom = float(headroom)
        self.relax_patience = max(1, int(relax_patience))
        self._tight: dict[str, int] = {c.name: 0 for c in book}
        self._drift: dict[str, float] = {c.name: 0.0 for c in book}
        self._calm: dict[str, int] = {c.name: 0 for c in book}
        self._served: dict[str, int] = {c.name: 0 for c in book}
        # backoff state is observable: the trace-dir metric snapshot shows
        # which classes ever tightened, and how deep, without a debugger
        self._registry = registry if registry is not None else get_registry()
        self.adopt(ladder)

    # ------------------------------------------------------------------ state
    def adopt(self, ladder) -> None:
        """(Re)bind to a ladder — startup and watcher-refresh path.  Caps
        recompute against the new predicted drifts; measured backoffs
        carry over (clamped)."""
        self.ladder = ladder
        self.caps = {}
        for c in self.book:
            cap = 0
            for i, plan in enumerate(ladder.plans):
                if plan.predicted_total <= c.drift_budget:
                    cap = i
            self.caps[c.name] = cap
            self._tight[c.name] = min(self._tight[c.name], cap)

    @property
    def top_level(self) -> int:
        return len(self.ladder) - 1

    def cap(self, name: str) -> int:
        return max(0, self.caps[name] - self._tight[name])

    def level_for(self, name: str, global_level: int | None = None) -> int:
        g = self.top_level if global_level is None else int(global_level)
        return min(g, self.cap(name))

    def wants_shadow(self, name: str) -> bool:
        """Per-class shadow cadence: every ``shadow_every``-th batch *of
        that class*.  Keying on the global batch index would alias with
        the deterministic priority drain (a class always landing on odd
        indices would never be measured and its backoff never engage).
        Counts the call, so invoke exactly once per served batch."""
        i = self._served[name]
        self._served[name] = i + 1
        return i % self.shadow_every == 0

    # ---------------------------------------------------------------- control
    def observe(self, name: str, drift: float) -> bool:
        """Fold one measured shadow drift into the class's EWMA; tighten
        the class one level on overrun, relax after sustained headroom.
        Returns whether the class's backoff changed."""
        a = self.ewma_alpha
        self._drift[name] = a * max(0.0, float(drift)) \
            + (1 - a) * self._drift[name]
        budget = self.book.get(name).drift_budget
        if self._drift[name] > budget and self.cap(name) > 0:
            self._tight[name] += 1
            self._calm[name] = 0
            # decay the EWMA toward the budget so one spike does not keep
            # ratcheting the class down on every subsequent sample
            self._drift[name] = budget * self.headroom
            self._note_backoff(name, "tighten")
            return True
        if self._drift[name] <= budget * self.headroom \
                and self._tight[name] > 0:
            self._calm[name] += 1
            if self._calm[name] >= self.relax_patience:
                self._tight[name] -= 1
                self._calm[name] = 0
                self._note_backoff(name, "relax")
                return True
        else:
            self._calm[name] = 0
        return False

    def _note_backoff(self, name: str, move: str) -> None:
        self._registry.counter("class_backoff_moves_total", move=move,
                               **{"class": name}).inc()
        self._registry.gauge("class_backoff_level",
                             **{"class": name}).set(self._tight[name])

    def measured_drift(self, name: str) -> float:
        return self._drift[name]

    def snapshot(self, global_level: int | None = None) -> dict:
        """Per-class state for telemetry / bench dumps."""
        return {
            c.name: {
                "drift_budget": c.drift_budget,
                "slo_ms": c.slo_ms,
                "cap": self.cap(c.name),
                "level": self.level_for(c.name, global_level),
                "ewma_drift": round(self._drift[c.name], 6),
            }
            for c in self.book
        }
