"""Online per-layer sensitivity estimation from shadow-step samples.

The serving engine already measures real logit drift every
``shadow_every`` batches (live stack vs exact stack on cache copies).
That sample is a *total* over all layers; this estimator folds it back
into per-layer sensitivities by attributing the measured drift to layers
in proportion to the drift the current estimates predict for the plan
that produced it — layer ``l`` carrying operator mae ``m_l`` gets share
``s_l·m_l / Σ_j s_j·m_j`` of the total, and its implied sensitivity
``share·drift / m_l`` updates an EWMA.

Identifiability mirrors the physics: one fixed plan only pins the
weighted sum ``Σ s_l·m_l`` (each update rescales the estimate vector to
match the measured total, preserving ratios), but an adaptive serve never
holds one plan — the controller walks the ladder and per-class traffic
decodes on different levels, so successive samples carry *different* mae
vectors and the per-layer components separate.  The convergence test
drives exactly that: synthetic drift from varied plans pulls the
estimates to the offline profile.

Exact layers (``m_l = 0``) are silent in a sample and keep their current
estimate — attribution never divides by an exact layer's zero mae.
"""

from __future__ import annotations

import numpy as np

__all__ = ["OnlineSensitivity"]


class OnlineSensitivity:
    """Per-layer EWMA sensitivities (drift per unit operator mae)."""

    def __init__(self, n_layers: int, *, alpha: float = 0.25,
                 init=None) -> None:
        assert 0 < alpha <= 1
        self.alpha = float(alpha)
        if init is None:
            self.sens = np.ones(n_layers, dtype=np.float64)
        else:
            self.sens = np.asarray(init, dtype=np.float64).copy()
            assert self.sens.shape == (n_layers,)
        assert (self.sens >= 0).all()
        self.n_updates = 0

    @classmethod
    def from_profile(cls, profile, bits, *, alpha: float = 0.25,
                     width_map=None) -> "OnlineSensitivity":
        """Seed from an offline :class:`~repro.sensitivity.profile.SensitivityProfile`
        — per-width, or per-layer-width under a mixed ``width_map``."""
        if width_map is not None:
            init = np.array([profile.sensitivities(b)[l]
                             for l, b in enumerate(width_map)])
            return cls(len(width_map), alpha=alpha, init=init)
        return cls(profile.n_layers, alpha=alpha,
                   init=profile.sensitivities(bits))

    def update(self, maes, drift: float) -> None:
        """Fold one shadow sample in.  ``maes[l]`` is the compiled-table
        mae of the operator layer ``l`` ran in the sampled batch (0 for
        exact layers); ``drift`` is the measured total mean |Δlogit|."""
        m = np.asarray(maes, dtype=np.float64)
        assert m.shape == self.sens.shape
        active = m > 0
        if not active.any():
            return      # all-exact plan: the sample carries no signal
        d = max(0.0, float(drift))
        pred = self.sens * m
        total = float(pred[active].sum())
        if total > 0:
            shares = np.where(active, pred / total, 0.0)
        else:       # estimates collapsed to 0: split evenly over active
            shares = active / active.sum()
        obs = np.zeros_like(self.sens)
        obs[active] = d * shares[active] / m[active]
        a = self.alpha
        self.sens = np.where(active, (1 - a) * self.sens + a * obs,
                             self.sens)
        self.n_updates += 1

    def sensitivities(self) -> np.ndarray:
        return self.sens.copy()
