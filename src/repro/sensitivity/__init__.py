"""Measured sensitivity: the fourth pillar (search → library →
**sensitivity** → serving).

The paper's premise is spending area/accuracy budget where it buys the
most; everything upstream of this package produces the operators and the
runtime, and this package produces the *measurements* that decide where
the budget goes:

* :mod:`repro.sensitivity.profile` — offline per-layer drift profiling:
  perturb one layer at a time against the exact oracle (deterministic
  truncation probes, optional full per-(layer, operator) matrices over a
  library's frontier) and persist a :class:`~repro.sensitivity.profile.SensitivityProfile`
  next to the library.  ``python -m repro.sensitivity.profile`` is the
  producer; the serve launcher's ``--profile`` and
  ``examples/approx_inference.py`` are the consumers.
* :mod:`repro.sensitivity.online` — fold the serving engine's shadow-step
  drift samples into per-layer EWMA sensitivities, attributed by the
  operator each plan assigned per layer.
* :mod:`repro.sensitivity.classes` — per-request QoS classes: named
  traffic tiers with their own drift budgets; the request queue,
  controller and telemetry are class-aware, so ``gold`` decodes on a more
  exact plan than ``batch`` in the same serve.

``online``/``classes`` are numpy-only; ``profile`` pulls in the jax model
stack and is lazy here (same PEP 562 arrangement as ``repro.library``).
"""

from .classes import ClassBook, ClassScheduler, QoSClass, parse_class_mix
from .online import OnlineSensitivity

_LAZY = {
    "Probe": ".profile",
    "SensitivityProfile": ".profile",
    "truncation_probe": ".profile",
    "model_eval_drift": ".profile",
    "measure_profile": ".profile",
    "measure_cost_matrix": ".profile",
    "costs_for": ".profile",
    "default_profile_path": ".profile",
    "load_profile": ".profile",
}


def __getattr__(name: str):
    if name in _LAZY:
        from importlib import import_module

        value = getattr(import_module(_LAZY[name], __name__), name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "QoSClass",
    "ClassBook",
    "ClassScheduler",
    "parse_class_mix",
    "OnlineSensitivity",
    "Probe",
    "SensitivityProfile",
    "truncation_probe",
    "model_eval_drift",
    "measure_profile",
    "measure_cost_matrix",
    "costs_for",
    "default_profile_path",
    "load_profile",
]
