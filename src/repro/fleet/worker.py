"""Fleet workers: run :class:`SearchJob`\\ s, commit results to one store.

Execution model (see the package docstring): CPU-bound engines (SMT,
anneal, muscat, mecals) are pure numpy/z3 and fork cheaply, so they fan
out over a ``multiprocessing`` pool; ``tensor`` jobs stay in the parent
process where the population is sharded over the jax mesh ``data`` axis
— forking a process per tensor job would fight jax for the same devices.

Every finished job writes a receipt under ``<library>/_fleet/`` keyed by
:meth:`SearchJob.key` plus a digest of its engine options; a later run of
the same sweep skips receipted jobs (status ``ok``) entirely, which
together with the store's content-addressing makes resume a no-op — while
a sweep with *changed* engine options re-executes.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import multiprocessing
import os
import time
import traceback
from dataclasses import dataclass, field
from functools import partial
from pathlib import Path

from ..core.engine import SearchJob, available_engines, get_engine
from ..library.store import OperatorStore, atomic_write_json
from ..obs.export import dump_metrics
from ..obs.metrics import get_registry
from ..obs.trace import current_tracer
from ..obs.trace import span as trace_span

__all__ = ["JobResult", "run_job", "run_sweep", "flag_outlier_jobs",
           "RECEIPT_DIR"]

RECEIPT_DIR = "_fleet"   # skipped by OperatorStore.signatures() (not a signature)


@dataclass
class JobResult:
    """What one job did — enough for the CLI's run table."""

    job: SearchJob
    status: str               # "ok" | "skipped" | "failed"
    n_results: int = 0
    wall_s: float = 0.0
    engine_s: float = 0.0     # pure engine time (no receipt/commit IO)
    error: str | None = None
    stats: dict = field(default_factory=dict)   # engine stats (ok jobs)


def _flush_worker_obs() -> None:
    """Snapshot this process's metrics into the trace dir (if tracing).

    Pool workers call this at the end of every job: the snapshot file is
    per-process and atomically replaced, so repeated flushes just widen
    that worker's cumulative view and the parent's read-time merge sees
    whatever each worker last completed — crash included.
    """
    tracer = current_tracer()
    if tracer is not None:
        dump_metrics(tracer.root, get_registry())


def _receipt_path(library_root: str | os.PathLike, job: SearchJob,
                  opts: dict) -> Path:
    """Receipt file for (job, engine options).

    The options digest is part of the name: re-running a sweep with
    changed ``engine_opts`` (more generations, deeper annealing) must
    re-execute the job, not silently skip it on the old receipt.
    """
    opts_key = hashlib.sha256(
        json.dumps(opts, sort_keys=True).encode()
    ).hexdigest()[:8]
    return Path(library_root) / RECEIPT_DIR / f"{job.key()}-{opts_key}.json"


def run_job(job: SearchJob, library_root: str | os.PathLike,
            engine_opts: dict | None = None, mesh=None) -> JobResult:
    """Run one job and commit every sound candidate into the shared store.

    Top-level (picklable) so a multiprocessing pool can map over it.
    """
    t0 = time.time()
    opts = dict((engine_opts or {}).get(job.engine, {}))
    receipt = _receipt_path(library_root, job, opts)
    if receipt.is_file():
        try:
            prior = json.loads(receipt.read_text())
        except json.JSONDecodeError:
            prior = {}
        if prior.get("status") == "ok":   # failed jobs are retried
            return JobResult(job, "skipped",
                             n_results=int(prior.get("n_results", 0)))

    ctor_opts = dict(opts)   # mesh is runtime wiring, not part of the receipt
    if job.engine == "tensor" and mesh is not None:
        ctor_opts["mesh"] = mesh
    store = OperatorStore(library_root)
    reg = get_registry()
    with trace_span("fleet.job", engine=job.engine,
                    benchmark=job.benchmark_name, et=job.et,
                    metric=job.error_metric, seed=job.seed,
                    key=job.key()) as sp:
        try:
            t_eng = time.time()
            outcome = get_engine(job.engine, **ctor_opts).run(job)
            engine_s = time.time() - t_eng
            sig = job.signature()
            t_commit = time.time()
            for cand in outcome.results:
                store.put_circuit(
                    cand.circuit, sig, area=cand.area, source=job.engine,
                    proxies=cand.proxies, params=cand.params,
                    meta={**cand.meta, "wall_s": cand.wall_s,
                          "job": job.key()},
                )
            commit_s = time.time() - t_commit
        except Exception as exc:
            sp.set(status="failed", error=f"{type(exc).__name__}: {exc}")
            reg.counter("fleet_jobs_total", engine=job.engine,
                        status="failed").inc()
            atomic_write_json(receipt, {
                "status": "failed",
                "job": dataclasses.asdict(job),
                "engine_opts": opts,
                "error": f"{type(exc).__name__}: {exc}",
                "traceback": traceback.format_exc(limit=8),
                "wall_s": round(time.time() - t0, 3),
            })
            _flush_worker_obs()
            return JobResult(job, "failed", wall_s=time.time() - t0,
                             error=f"{type(exc).__name__}: {exc}")
        sp.set(status="ok", n_results=len(outcome.results),
               engine_s=round(engine_s, 4), commit_s=round(commit_s, 4))

    reg.counter("fleet_jobs_total", engine=job.engine, status="ok").inc()
    reg.histogram("fleet_job_s", engine=job.engine).observe(time.time() - t0)
    atomic_write_json(receipt, {
        "status": "ok",
        "job": dataclasses.asdict(job),
        "engine_opts": opts,
        "n_results": len(outcome.results),
        "stats": outcome.stats,
        "engine_s": round(engine_s, 4),
        "commit_s": round(commit_s, 4),
        "wall_s": round(time.time() - t0, 3),
    })
    _flush_worker_obs()
    return JobResult(job, "ok", n_results=len(outcome.results),
                     wall_s=time.time() - t0, engine_s=engine_s,
                     stats=dict(outcome.stats))


def flag_outlier_jobs(results: list[JobResult], *, threshold: float = 4.0,
                      min_group: int = 4) -> list[tuple[JobResult, float]]:
    """Flag jobs whose engine wall-time is a robust-z outlier among the
    ``ok`` jobs sharing their (engine, signature) group — the fleet-side
    consumer of the health plane's detector math.  A straggling SMT
    solve or a pathological anneal seed shows up here instead of hiding
    in the sweep's total.  Groups smaller than ``min_group`` are skipped
    (median/MAD over 2–3 samples flags noise, not outliers).  Flagged
    jobs are counted (``fleet_job_outliers_total{engine}``) and traced
    (``fleet.outlier``), and returned with their z-scores."""
    from ..obs.anomaly import robust_zscores
    from ..obs.trace import event as trace_event

    groups: dict[tuple, list[JobResult]] = {}
    for r in results:
        if r.status != "ok" or r.engine_s <= 0:
            continue
        key = (r.job.engine, r.job.benchmark, r.job.bits,
               r.job.error_metric, r.job.et)
        groups.setdefault(key, []).append(r)
    reg = get_registry()
    flagged: list[tuple[JobResult, float]] = []
    for rs in groups.values():
        if len(rs) < min_group:
            continue
        for r, z in zip(rs, robust_zscores([x.engine_s for x in rs])):
            if abs(z) < threshold:
                continue
            flagged.append((r, z))
            reg.counter("fleet_job_outliers_total",
                        engine=r.job.engine).inc()
            trace_event("fleet.outlier", key=r.job.key(),
                        engine=r.job.engine,
                        engine_s=round(r.engine_s, 4), zscore=round(z, 2))
    return flagged


def run_sweep(spec, library_root: str | os.PathLike, *,
              workers: int | None = None,
              log=print) -> list[JobResult]:
    """Plan ``spec``, run every job, return per-job results.

    ``workers``: pool size for the CPU engines (0/1 = run everything
    sequentially in-process — deterministic, used by tests).  Engines the
    image cannot run (SMT without z3) are dropped with a notice.
    """
    from .plan import plan_jobs

    jobs = plan_jobs(spec)
    runnable = set(available_engines())
    dropped = {j for j in jobs if j.engine not in runnable}
    if dropped:
        log(f"fleet: skipping {len(dropped)} job(s) on unavailable engines "
            f"{sorted({j.engine for j in dropped})} (z3 missing?)")
    tensor_jobs = [j for j in jobs if j.engine == "tensor" and j not in dropped]
    cpu_jobs = [j for j in jobs if j.engine != "tensor" and j not in dropped]

    results: list[JobResult] = []
    worker = partial(run_job, library_root=str(library_root),
                     engine_opts=spec.engine_opts)
    if workers and workers > 1 and len(cpu_jobs) > 1:
        # CPU engines are numpy/z3-only, so fork is cheap — but only while
        # jax (multithreaded) has not been imported into this process;
        # otherwise fall back to spawn to dodge the fork-with-threads trap.
        import sys

        method = "fork" if "jax" not in sys.modules else "spawn"
        try:
            ctx = multiprocessing.get_context(method)
        except ValueError:  # pragma: no cover - non-POSIX fallback
            ctx = multiprocessing.get_context()
        with ctx.Pool(min(workers, len(cpu_jobs))) as pool:
            results.extend(pool.map(worker, cpu_jobs))
    else:
        results.extend(worker(j) for j in cpu_jobs)

    if tensor_jobs:
        mesh = None
        import jax

        if jax.device_count() > 1:
            from ..launch.mesh import make_fleet_mesh

            mesh = make_fleet_mesh()
        for j in tensor_jobs:
            results.append(run_job(j, library_root,
                                   engine_opts=spec.engine_opts, mesh=mesh))

    for r in results:
        log(f"  {r.job.describe():58s} {r.status:8s} "
            f"{r.n_results:3d} result(s) {r.wall_s:6.1f}s"
            + (f"  {r.error}" if r.error else ""))
    return results
