"""Distributed operator-library filling: plan → workers → one shared store.

The searches in :mod:`repro.core` each find operators one benchmark at a
time; the library (:mod:`repro.library`) only pays off when its frontier
is *dense* across benchmarks, bit widths and error thresholds.  This
package runs that densification as a fleet:

* :mod:`repro.fleet.plan` — expands a **sweep spec** into a deterministic
  list of :class:`~repro.core.engine.SearchJob`\\ s (the cross product
  benchmarks × bits × ET grid × engines, with per-job seeds derived
  stably from the spec seed).
* :mod:`repro.fleet.worker` — runs jobs against the unified engine
  registry and commits every sound :class:`~repro.core.engine.Candidate`
  into one shared :class:`~repro.library.OperatorStore`.  CPU engines
  (SMT / anneal / rewrite) fan out over a multiprocessing pool; the
  ``tensor`` engine runs in-process with its population sharded over the
  jax mesh ``data`` axis (:func:`repro.launch.mesh.make_fleet_mesh`), so
  one worker drives every local TPU chip.
* ``python -m repro.fleet`` — the CLI; prints an end-of-run
  frontier-densification report (operators added, per-signature record
  and frontier counts before/after).

Resume is free twice over: the store is content-addressed (re-finding a
netlist is a no-op ``put``), and each completed job leaves a receipt
under ``<library>/_fleet/<job-key>.json`` that later runs skip.

Sweep-spec format
-----------------
``--sweep`` takes a named preset (``smoke``, ``nightly``) or a path to a
JSON file::

    {
      "name": "my-sweep",
      "benchmarks": ["mul", "adder"],        // operator kinds
      "bits": [2, 3, 4],                     // operand bit widths
      "ets": [1, 2, 4],                      // absolute thresholds, and/or
      "et_fracs": [0.0625, 0.25],            // fractions of the max exact
                                             //   output value (per kind/bits)
      "engines": ["shared", "tensor", "anneal"],
      "budget_s": 60.0,                      // wall budget per job
      "seed": 0,                             // base seed; job seeds derive
      "engine_opts": {                       // engine constructor knobs
        "tensor": {"population": 1024, "generations": 40},
        "anneal": {"steps": 4000, "restarts": 3}
      }
    }

Every field except ``benchmarks`` / ``bits`` / ``engines`` and one of
``ets`` / ``et_fracs`` is optional.  Engines the image cannot run (the
SMT pair without z3) are skipped with a notice rather than failing the
sweep.

Example::

    python -m repro.fleet --library runs/lib --sweep smoke
    python -m repro.fleet --library runs/lib --sweep nightly --workers 8
"""

from .plan import SWEEPS, SweepSpec, load_spec, plan_jobs
from .worker import JobResult, run_job, run_sweep

__all__ = [
    "SweepSpec",
    "SWEEPS",
    "load_spec",
    "plan_jobs",
    "JobResult",
    "run_job",
    "run_sweep",
]
