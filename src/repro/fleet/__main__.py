"""``python -m repro.fleet --library <dir> --sweep <spec>`` — run a sweep
and report how much denser the operator frontier got.

``--trace <dir>`` (or just ``--trace``, defaulting to
``<library>/_fleet/trace``) turns on the observability plane: every job
runs under a ``fleet.job`` span (engine search spans nested inside),
worker processes append to their own span files in the shared trace dir
and snapshot their metric registries there, and the end-of-run report
prints the five slowest jobs plus per-engine wall-time totals straight
from the merged trace.  ``python -m repro.obs summary --trace <dir>``
re-reads the same directory later.

Exit status is non-zero when ``--min-new`` is set and the sweep added
fewer operators than that (CI smoke gate); resumed no-op runs pass with
``--min-new 0`` (the default).
"""

from __future__ import annotations

import argparse
import sys
import time

from pathlib import Path

from ..library.pareto import frontier_sizes
from ..library.store import OperatorStore, atomic_write_json
from ..obs.export import dump_metrics
from ..obs.metrics import get_registry
from ..obs.trace import configure as configure_tracing
from ..obs.trace import read_trace
from .plan import SWEEPS, load_spec, plan_jobs
from .worker import RECEIPT_DIR, flag_outlier_jobs, run_sweep


def notify_store_update(store: OperatorStore, *, sweep: str,
                        added: int) -> None:
    """Store-change notification: stamp ``<library>/_fleet/last_update.json``
    with the post-sweep :meth:`~repro.library.store.OperatorStore.version_token`.
    A serving-side :class:`repro.serving.watcher.LibraryWatcher` detects the
    change through the token itself; the stamp is the human/ops-facing
    record of *which* sweep moved it and when."""
    atomic_write_json(Path(store.root) / RECEIPT_DIR / "last_update.json", {
        "sweep": sweep,
        "added": added,
        "version_token": store.version_token(),
        "finished_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    })


def trace_report(trace_dir: Path, job_keys: set[str], *,
                 limit: int = 5, out=print) -> None:
    """The end-of-run view of *this* sweep's trace: slowest jobs and
    per-engine wall-time, filtered to the run's job keys (the trace dir
    may hold spans from earlier resumed runs)."""
    jobs = [s for s in read_trace(trace_dir)
            if s["name"] == "fleet.job"
            and s.get("attrs", {}).get("key") in job_keys]
    if not jobs:
        return
    out(f"\ntrace ({trace_dir}):")
    out(f"  slowest {min(limit, len(jobs))} job(s):")
    for s in sorted(jobs, key=lambda s: -float(s.get("dur_s", 0.0)))[:limit]:
        a = s.get("attrs", {})
        out(f"    {float(s.get('dur_s', 0.0)):8.2f}s  {a.get('engine', '?'):8s}"
            f" {a.get('benchmark', '?'):10s} et={a.get('et', '?')} "
            f"status={a.get('status', '?')} "
            f"results={a.get('n_results', 0)}")
    by_engine: dict[str, list[float]] = {}
    for s in jobs:
        eng = str(s.get("attrs", {}).get("engine", "?"))
        by_engine.setdefault(eng, []).append(float(s.get("dur_s", 0.0)))
    out("  per-engine wall-time:")
    for eng in sorted(by_engine, key=lambda e: -sum(by_engine[e])):
        ds = by_engine[eng]
        out(f"    {eng:8s} {len(ds):3d} job(s) {sum(ds):8.2f}s total "
            f"{sum(ds) / len(ds):7.2f}s mean")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet",
        description="Fill the approximate-operator library with a job fleet.",
    )
    ap.add_argument("--library", required=True,
                    help="shared operator-store directory (created if missing)")
    ap.add_argument("--sweep", default="smoke",
                    help=f"preset ({', '.join(SWEEPS)}) or JSON spec path")
    ap.add_argument("--workers", type=int, default=None,
                    help="process-pool size for CPU engines "
                         "(default: min(4, cpu count); 0/1 = sequential)")
    ap.add_argument("--budget-s", type=float, default=None,
                    help="override the spec's per-job wall budget")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the spec's base seed")
    ap.add_argument("--min-new", type=int, default=0,
                    help="fail unless at least this many operators were added")
    ap.add_argument("--trace", nargs="?", const="", default=None,
                    metavar="DIR",
                    help="write an observability trace (spans + metric "
                         "snapshots); DIR defaults to <library>/_fleet/trace")
    args = ap.parse_args(argv)

    trace_dir = None
    if args.trace is not None:
        trace_dir = Path(args.trace) if args.trace \
            else Path(args.library) / RECEIPT_DIR / "trace"
        configure_tracing(trace_dir)   # exports REPRO_TRACE_DIR to workers

    spec = load_spec(args.sweep, budget_s=args.budget_s, seed=args.seed)
    workers = args.workers
    if workers is None:
        import os

        workers = min(4, os.cpu_count() or 1)

    store = OperatorStore(args.library)
    before = frontier_sizes(store)
    n_before = sum(n for n, _ in before.values())
    jobs = plan_jobs(spec)
    print(f"sweep {spec.name!r}: {len(jobs)} job(s) -> "
          f"{args.library} ({n_before} operator(s) already stored)")
    t0 = time.time()
    results = run_sweep(spec, args.library, workers=workers)
    after = frontier_sizes(store)

    # ---- frontier-densification report ------------------------------------
    n_after = sum(n for n, _ in after.values())
    added = n_after - n_before
    print(f"\nfrontier densification ({time.time() - t0:.1f}s wall):")
    print(f"  {'signature':18s} {'records':>15s} {'frontier':>15s}")
    for name in sorted(set(before) | set(after)):
        nb, fb = before.get(name, (0, 0))
        na, fa = after.get(name, (0, 0))
        print(f"  {name:18s} {nb:6d} -> {na:<6d} {fb:6d} -> {fa:<6d}")
    if added:
        notify_store_update(store, sweep=spec.name, added=added)
    n_ok = sum(r.status == "ok" for r in results)
    n_skip = sum(r.status == "skipped" for r in results)
    n_fail = sum(r.status == "failed" for r in results)
    print(f"jobs: {n_ok} ok, {n_skip} resumed/skipped, {n_fail} failed; "
          f"{added} operator(s) added under "
          f"{sum(1 for s in after if after[s][0] > before.get(s, (0, 0))[0])} "
          f"signature(s)")
    outliers = flag_outlier_jobs(results)
    for r, z in outliers:
        print(f"  OUTLIER {r.job.describe():58s} engine_s={r.engine_s:.2f} "
              f"(robust z={z:+.1f} among its signature's jobs)")
    if trace_dir is not None:
        # the parent's own registry (tensor jobs run in-process) joins the
        # workers' snapshots before the report reads the merged dir back
        dump_metrics(trace_dir, get_registry())
        trace_report(trace_dir, {j.key() for j in jobs})
    if added < args.min_new:
        print(f"FAIL: added {added} < --min-new {args.min_new}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
