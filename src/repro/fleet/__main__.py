"""``python -m repro.fleet --library <dir> --sweep <spec>`` — run a sweep
and report how much denser the operator frontier got.

Exit status is non-zero when ``--min-new`` is set and the sweep added
fewer operators than that (CI smoke gate); resumed no-op runs pass with
``--min-new 0`` (the default).
"""

from __future__ import annotations

import argparse
import sys
import time

from pathlib import Path

from ..library.pareto import frontier_sizes
from ..library.store import OperatorStore, atomic_write_json
from .plan import SWEEPS, load_spec, plan_jobs
from .worker import RECEIPT_DIR, run_sweep


def notify_store_update(store: OperatorStore, *, sweep: str,
                        added: int) -> None:
    """Store-change notification: stamp ``<library>/_fleet/last_update.json``
    with the post-sweep :meth:`~repro.library.store.OperatorStore.version_token`.
    A serving-side :class:`repro.serving.watcher.LibraryWatcher` detects the
    change through the token itself; the stamp is the human/ops-facing
    record of *which* sweep moved it and when."""
    atomic_write_json(Path(store.root) / RECEIPT_DIR / "last_update.json", {
        "sweep": sweep,
        "added": added,
        "version_token": store.version_token(),
        "finished_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    })


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet",
        description="Fill the approximate-operator library with a job fleet.",
    )
    ap.add_argument("--library", required=True,
                    help="shared operator-store directory (created if missing)")
    ap.add_argument("--sweep", default="smoke",
                    help=f"preset ({', '.join(SWEEPS)}) or JSON spec path")
    ap.add_argument("--workers", type=int, default=None,
                    help="process-pool size for CPU engines "
                         "(default: min(4, cpu count); 0/1 = sequential)")
    ap.add_argument("--budget-s", type=float, default=None,
                    help="override the spec's per-job wall budget")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the spec's base seed")
    ap.add_argument("--min-new", type=int, default=0,
                    help="fail unless at least this many operators were added")
    args = ap.parse_args(argv)

    spec = load_spec(args.sweep, budget_s=args.budget_s, seed=args.seed)
    workers = args.workers
    if workers is None:
        import os

        workers = min(4, os.cpu_count() or 1)

    store = OperatorStore(args.library)
    before = frontier_sizes(store)
    n_before = sum(n for n, _ in before.values())
    print(f"sweep {spec.name!r}: {len(plan_jobs(spec))} job(s) -> "
          f"{args.library} ({n_before} operator(s) already stored)")
    t0 = time.time()
    results = run_sweep(spec, args.library, workers=workers)
    after = frontier_sizes(store)

    # ---- frontier-densification report ------------------------------------
    n_after = sum(n for n, _ in after.values())
    added = n_after - n_before
    print(f"\nfrontier densification ({time.time() - t0:.1f}s wall):")
    print(f"  {'signature':18s} {'records':>15s} {'frontier':>15s}")
    for name in sorted(set(before) | set(after)):
        nb, fb = before.get(name, (0, 0))
        na, fa = after.get(name, (0, 0))
        print(f"  {name:18s} {nb:6d} -> {na:<6d} {fb:6d} -> {fa:<6d}")
    if added:
        notify_store_update(store, sweep=spec.name, added=added)
    n_ok = sum(r.status == "ok" for r in results)
    n_skip = sum(r.status == "skipped" for r in results)
    n_fail = sum(r.status == "failed" for r in results)
    print(f"jobs: {n_ok} ok, {n_skip} resumed/skipped, {n_fail} failed; "
          f"{added} operator(s) added under "
          f"{sum(1 for s in after if after[s][0] > before.get(s, (0, 0))[0])} "
          f"signature(s)")
    if added < args.min_new:
        print(f"FAIL: added {added} < --min-new {args.min_new}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
