"""Sweep-spec expansion: one spec in, a deterministic job list out.

The planner is pure bookkeeping — no search code runs here — so a plan
can be printed, diffed and re-derived bit-identically on any machine:
job ordering follows the spec's field order, and each job's seed is an
SHA-256 derivation of ``(spec.seed, kind, bits, et, engine)``, so adding
a benchmark to a sweep never reshuffles the seeds of existing jobs.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field, replace
from pathlib import Path

from ..core.engine import ENGINE_NAMES, SearchJob

__all__ = ["SweepSpec", "SWEEPS", "load_spec", "plan_jobs", "ets_for"]


@dataclass(frozen=True)
class SweepSpec:
    """Declarative description of a fleet sweep (see the package docstring
    for the on-disk JSON format)."""

    name: str
    benchmarks: tuple[str, ...]          # operator kinds: "mul" / "adder"
    bits: tuple[int, ...]                # operand bit widths
    engines: tuple[str, ...]             # engine registry names
    ets: tuple[int, ...] = ()            # absolute error thresholds
    et_fracs: tuple[float, ...] = ()     # and/or fractions of max output
    budget_s: float = 30.0               # wall budget per job
    seed: int = 0
    engine_opts: dict = field(default_factory=dict)
    # one-off jobs appended after the grid: dicts with benchmark/bits/et/
    # engine and optionally error_metric / budget_s.  This is how a sweep
    # mixes error metrics (an mae miter job riding along a wce grid)
    # without multiplying the whole grid by every metric.
    extra_jobs: tuple[dict, ...] = ()

    def __post_init__(self) -> None:
        extra_engines = tuple(j["engine"] for j in self.extra_jobs)
        for eng in self.engines + extra_engines:
            if eng not in ENGINE_NAMES:
                raise ValueError(f"unknown engine {eng!r} in sweep "
                                 f"{self.name!r}; known: {ENGINE_NAMES}")
        if not (self.ets or self.et_fracs or self.extra_jobs):
            raise ValueError(f"sweep {self.name!r} has neither ets nor et_fracs")


# Named presets.  ``smoke`` is the CI / acceptance sweep: 2-bit only, no
# z3 needed, engines bounded by step/generation counts (not wall time) so
# a re-run reproduces the exact same netlists.
SWEEPS: dict[str, SweepSpec] = {
    "smoke": SweepSpec(
        name="smoke",
        benchmarks=("adder", "mul"),
        bits=(2,),
        ets=(1, 2),
        engines=("anneal", "tensor"),
        budget_s=60.0,  # safety net only; step/generation counts bound work
        engine_opts={
            "tensor": {"population": 512, "generations": 24, "elites": 64,
                       "keep": 4},
            "anneal": {"steps": 8000, "restarts": 4, "keep": 4},
        },
        # one mean-metric job rides along: an mae-bounded 2-bit multiplier
        # search (the anneal engine scores mae natively; the store
        # validates the mae signature at write time)
        extra_jobs=(
            {"benchmark": "mul", "bits": 2, "et": 1, "engine": "anneal",
             "error_metric": "mae"},
        ),
    ),
    # densify the *composed W8A8* frontier: every stored mul block lowers
    # to a 256x256 table via repro.precision.compose, so what matters for
    # 8-bit serving is tight block error (nibble shift-add amplifies a
    # block's wce by up to 289x) at both searched widths.  The template
    # engines carry the 2-bit blocks; the rewrite baselines are what
    # reliably crack the 4-bit multiplier under bounded CPU budgets.
    # Everything is z3-free and step-bounded so CI reproduces the sweep.
    "8bit": SweepSpec(
        name="8bit",
        benchmarks=("mul",),
        bits=(2, 4),
        ets=(1, 2, 4, 8),
        engines=("anneal", "tensor", "muscat", "mecals"),
        budget_s=25.0,  # safety net; step/generation counts bound the work
        engine_opts={
            "tensor": {"population": 256, "generations": 16, "elites": 32,
                       "keep": 3},
            "anneal": {"steps": 6000, "restarts": 3, "keep": 3},
        },
    ),
    "nightly": SweepSpec(
        name="nightly",
        benchmarks=("adder", "mul"),
        bits=(2, 3, 4),
        et_fracs=(1 / 32, 1 / 16, 1 / 8, 1 / 4),
        engines=("shared", "xpat", "tensor", "anneal", "muscat", "mecals"),
        budget_s=600.0,
    ),
}


def ets_for(spec: SweepSpec, kind: str, bits: int) -> tuple[int, ...]:
    """The sweep's ET grid for one (kind, bits): absolute ``ets`` plus
    ``et_fracs`` scaled by the exact operator's maximum output value."""
    ets = set(spec.ets)
    if spec.et_fracs:
        top = (1 << bits) - 1
        max_val = top * top if kind == "mul" else 2 * top
        ets.update(max(1, round(f * max_val)) for f in spec.et_fracs)
    return tuple(sorted(ets))


def job_seed(base_seed: int, kind: str, bits: int, et: int, engine: str,
             error_metric: str = "wce") -> int:
    """Stable per-job seed: independent of job ordering within the sweep.

    Non-default metrics extend the blob; the default leaves it unchanged
    so every pre-metric sweep keeps its exact historical seeds (and thus
    its reproducible netlists).
    """
    blob = f"{base_seed}|{kind}|{bits}|{et}|{engine}"
    if error_metric != "wce":
        blob += f"|{error_metric}"
    return int.from_bytes(hashlib.sha256(blob.encode()).digest()[:4], "big")


def plan_jobs(spec: SweepSpec) -> list[SearchJob]:
    """Expand a sweep spec into its full, deterministic job list (the
    grid first, then the spec's ``extra_jobs`` in declaration order)."""
    jobs: list[SearchJob] = []
    for kind in spec.benchmarks:
        for bits in spec.bits:
            for et in ets_for(spec, kind, bits):
                for engine in spec.engines:
                    jobs.append(SearchJob(
                        benchmark=kind, bits=bits, et=et, engine=engine,
                        budget_s=spec.budget_s,
                        seed=job_seed(spec.seed, kind, bits, et, engine),
                    ))
    for extra in spec.extra_jobs:
        kind, bits = extra["benchmark"], int(extra["bits"])
        et, engine = int(extra["et"]), extra["engine"]
        metric = extra.get("error_metric", "wce")
        jobs.append(SearchJob(
            benchmark=kind, bits=bits, et=et, engine=engine,
            error_metric=metric,
            budget_s=float(extra.get("budget_s", spec.budget_s)),
            seed=job_seed(spec.seed, kind, bits, et, engine, metric),
        ))
    return jobs


def load_spec(name_or_path: str, **overrides) -> SweepSpec:
    """Resolve ``--sweep``: a preset name or a JSON spec file path."""
    if name_or_path in SWEEPS:
        spec = SWEEPS[name_or_path]
    else:
        path = Path(name_or_path)
        if not path.is_file():
            raise FileNotFoundError(
                f"--sweep {name_or_path!r} is neither a preset "
                f"({', '.join(SWEEPS)}) nor a spec file"
            )
        doc = json.loads(path.read_text())
        spec = SweepSpec(
            name=doc.get("name", path.stem),
            benchmarks=tuple(doc["benchmarks"]),
            bits=tuple(int(b) for b in doc["bits"]),
            engines=tuple(doc["engines"]),
            ets=tuple(int(e) for e in doc.get("ets", ())),
            et_fracs=tuple(float(f) for f in doc.get("et_fracs", ())),
            budget_s=float(doc.get("budget_s", 30.0)),
            seed=int(doc.get("seed", 0)),
            engine_opts=dict(doc.get("engine_opts", {})),
            extra_jobs=tuple(dict(j) for j in doc.get("extra_jobs", ())),
        )
    overrides = {k: v for k, v in overrides.items() if v is not None}
    return replace(spec, **overrides) if overrides else spec
