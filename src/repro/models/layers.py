"""Layer primitives for the architecture zoo.

Everything is a pure function over explicit parameter pytrees (no module
framework): ``init_*`` builds params, the apply functions take
``(cfg, params, activations, ...)``.  Two execution modes share each
mixer: full-sequence (train / prefill) and single-step (decode, with an
explicit cache/state).  Sharding is annotated with *logical* axes via
:func:`repro.parallel.shard` — a no-op outside a mesh context.

Attention dispatch: the einsum path is the reference and supports a
*traced* window size (needed for gemma3's per-layer local/global pattern
inside one ``lax.scan``); the Pallas flash kernel is used on TPU for
uniform-window/causal layers.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..parallel import axis_extent, shard
from ..quant.int4 import approx_linear
from .config import ModelConfig

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def _dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms / rope / linear
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * (1.0 + w)


def linear(x: jax.Array, w: jax.Array, lut: jax.Array | None = None) -> jax.Array:
    """Matmul, optionally routed through the approximate-multiplier LUT."""
    if lut is not None:
        return approx_linear(x, w, lut)
    return jnp.einsum("...d,df->...f", x, w)


def rope_tables(positions: jax.Array, head_dim: int, theta: float):
    """positions (...,) -> cos/sin tables (..., head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, hd); cos/sin (..., S, hd//2) — half-split rotation."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (einsum reference path; flash kernel on TPU)
# ---------------------------------------------------------------------------
def init_attention(cfg: ModelConfig, key) -> Params:
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.jnp_dtype
    ks = _keys(key, 4)
    p = {
        "wq": _dense_init(ks[0], (D, H * hd), dt),
        "wk": _dense_init(ks[1], (D, Hkv * hd), dt),
        "wv": _dense_init(ks[2], (D, Hkv * hd), dt),
        "wo": _dense_init(ks[3], (H * hd, D), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def _qkv(cfg: ModelConfig, p: Params, x: jax.Array):
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = linear(x, p["wq"]).reshape(B, S, H, hd)
    k = linear(x, p["wk"]).reshape(B, S, Hkv, hd)
    v = linear(x, p["wv"]).reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _masked_softmax_attn(q, k, v, q_pos, k_pos, window, k_valid=None,
                         f32_math: bool = True):
    """Flat-head einsum attention with causal + (traced) window masking.

    q (B, Sq, H, hd); k/v (B, Sk, Hkv, hd); q_pos (Sq,), k_pos (Sk,).
    ``window``: None, a Python int, or a traced scalar (-1 == global).

    GQA is handled by *repeating* KV up to H heads: the flat H axis shards
    cleanly over the 16-way ``model`` mesh axis (96/16 etc.), whereas a
    grouped (Hkv, rep) layout with Hkv=8 < 16 forces XLA to replicate the
    S^2 score tensor on every device (observed 10x HBM inflation).  The
    repeat itself is free under sharding: each device materializes only
    its own heads' copies.
    """
    B, Sq, H, hd = q.shape
    out_dtype = q.dtype
    Hkv = k.shape[2]
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    k = shard(k, "batch", None, "model", None)
    v = shard(v, "batch", None, "model", None)
    scale = 1.0 / np.sqrt(hd)
    if f32_math:
        q, k = q.astype(jnp.float32), k.astype(jnp.float32)
    # bf16 inputs + f32 accumulation (MXU-native) when f32_math is off
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = shard(logits, "batch", "model", None, None)
    mask = k_pos[None, :] <= q_pos[:, None]  # causal
    if window is not None:
        w = jnp.asarray(window)
        in_window = k_pos[None, :] > q_pos[:, None] - w
        mask = mask & jnp.where(w > 0, in_window, True)
    if k_valid is not None:
        mask = mask & k_valid[None, :]
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    if f32_math:
        v = v.astype(jnp.float32)
    else:
        probs = probs.astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v,
                     preferred_element_type=jnp.float32)
    out = shard(out, "batch", None, "model", None)
    # v's head dim may differ from q's (MLA: qk 192 vs v 128)
    return out.astype(out_dtype)


def attention_full(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,            # (B, S, D)
    window,                  # None | int | traced scalar (-1 = global)
    *,
    backend: str = "auto",
) -> jax.Array:
    B, S, _ = x.shape
    q, k, v = _qkv(cfg, p, x)
    pos = jnp.arange(S)
    cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = shard(q, "batch", None, "model", None)
    k = shard(k, "batch", None, "model", None)
    use_flash = (
        backend in ("pallas", "pallas_interpret")
        or (backend == "auto" and jax.default_backend() == "tpu")
    ) and (window is None or isinstance(window, int))
    if use_flash:
        out = ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=True, window=window, backend=backend,
        ).transpose(0, 2, 1, 3)
    else:
        out = _masked_softmax_attn(q, k, v, pos, pos, window,
                                   f32_math=cfg.attn_f32)
    out = shard(out, "batch", None, "model", None)
    return linear(out.reshape(B, S, -1), p["wo"])


def attention_decode(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,            # (B, 1, D)
    cache: dict[str, jax.Array],   # {"k","v"}: (B, C, Hkv, hd); C = cache slots
    pos: jax.Array,          # () int32 — absolute position of the new token
    window,                  # None | int — ring-buffer window if set
) -> tuple[jax.Array, dict[str, jax.Array]]:
    B = x.shape[0]
    q, k_new, v_new = _qkv(cfg, p, x)
    cos, sin = rope_tables(pos[None], cfg.hd, cfg.rope_theta)
    q = apply_rope(q, cos[None], sin[None])
    k_new = apply_rope(k_new, cos[None], sin[None])

    C = cache["k"].shape[1]
    slot = jnp.where(window is None, pos, pos % C) if window is not None else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    new_cache = {"k": k, "v": v}

    if window is not None:
        # ring buffer: slot i holds absolute position with (pos - C, pos]
        idx = jnp.arange(C)
        k_pos = jnp.where(idx <= slot, pos - slot + idx, pos - slot - C + idx)
        k_valid = (k_pos >= 0) & (k_pos > pos - C - 1)
    else:
        idx = jnp.arange(C)
        k_pos = idx
        k_valid = idx <= pos
    out = _masked_softmax_attn(q, k, v, pos[None], k_pos, None, k_valid,
                               f32_math=cfg.attn_f32)
    out = linear(out.reshape(B, 1, -1), p["wo"])
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek): compressed KV cache + absorbed decode
# ---------------------------------------------------------------------------
def init_mla(cfg: ModelConfig, key) -> Params:
    mla = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qk = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    dt = cfg.jnp_dtype
    ks = _keys(key, 5)
    return {
        "wq": _dense_init(ks[0], (D, H * qk), dt),
        "wdkv": _dense_init(ks[1], (D, mla.kv_lora_rank + mla.qk_rope_head_dim), dt),
        "wuk": _dense_init(ks[2], (mla.kv_lora_rank, H * mla.qk_nope_head_dim), dt),
        "wuv": _dense_init(ks[3], (mla.kv_lora_rank, H * mla.v_head_dim), dt),
        "wo": _dense_init(ks[4], (H * mla.v_head_dim, D), dt),
    }


def mla_attention_full(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    mla = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope_d, vd, R = (
        mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim, mla.kv_lora_rank
    )
    q = linear(x, p["wq"]).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    ckv_kr = linear(x, p["wdkv"])
    c_kv, k_rope = ckv_kr[..., :R], ckv_kr[..., R:]          # (B,S,R), (B,S,rope_d)
    k_nope = linear(c_kv, p["wuk"]).reshape(B, S, H, nope)
    v = linear(c_kv, p["wuv"]).reshape(B, S, H, vd)

    pos = jnp.arange(S)
    cos, sin = rope_tables(pos, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)      # single shared head
    k_rope = jnp.broadcast_to(k_rope, (B, S, H, rope_d))

    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    kfull = jnp.concatenate([k_nope, k_rope], axis=-1)
    out = _masked_softmax_attn(qfull, kfull, v, pos, pos, None,
                               f32_math=cfg.attn_f32)
    return linear(out.reshape(B, S, -1), p["wo"])


def mla_attention_decode(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                 # (B, 1, D)
    cache: dict[str, jax.Array],  # {"ckv": (B, C, R), "kr": (B, C, rope_d)}
    pos: jax.Array,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Absorbed-matrix MLA decode: attention runs directly over the
    compressed cache; ``wuk`` folds into the query, ``wuv`` into the output
    (DeepSeek-V2's serving trick — the cache stays R + rope_d wide)."""
    mla = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    nope, rope_d, vd, R = (
        mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim, mla.kv_lora_rank
    )
    q = linear(x, p["wq"]).reshape(B, 1, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_tables(pos[None], rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos[None], sin[None])

    ckv_kr = linear(x, p["wdkv"])
    c_new, kr_new = ckv_kr[..., :R], ckv_kr[..., R:]
    kr_new = apply_rope(kr_new[:, :, None, :], cos[None], sin[None])[:, :, 0]
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], c_new, (0, pos, 0))
    kr = jax.lax.dynamic_update_slice(cache["kr"], kr_new, (0, pos, 0))
    new_cache = {"ckv": ckv, "kr": kr}

    # absorb wuk into q: q'[b,h,r] = sum_n q_nope[b,h,n] wuk[r, h*n]
    wuk = p["wuk"].reshape(R, H, nope)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       wuk.astype(jnp.float32))             # (B, H, R)
    scale = 1.0 / np.sqrt(nope + rope_d)
    logits = (
        jnp.einsum("bhr,bcr->bhc", q_abs, ckv.astype(jnp.float32))
        + jnp.einsum("bhd,bcd->bhc", q_rope[:, 0].astype(jnp.float32),
                     kr.astype(jnp.float32))
    ) * scale
    C = ckv.shape[1]
    valid = jnp.arange(C) <= pos
    logits = jnp.where(valid[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhc,bcr->bhr", probs, ckv.astype(jnp.float32))  # (B,H,R)
    wuv = p["wuv"].reshape(R, H, vd)
    out = jnp.einsum("bhr,rhv->bhv", ctx, wuv.astype(jnp.float32))
    out = out.reshape(B, 1, H * vd).astype(x.dtype)
    return linear(out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# paged / per-row decode attention (continuous batching)
#
# The fixed-batch decode path above shares one scalar ``pos`` across the
# whole batch.  Continuous batching mixes requests at *different* sequence
# positions in one step, so these variants take ``pos`` as a (B,) vector
# plus an ``active`` (B,) mask; global-attention KV lives in a shared page
# pool indexed by per-slot page tables (repro.serving.kvcache) instead of
# a dense per-slot cache.  Everything stays pure jnp gather/scatter —
# shapes are fixed by (max_slots, pages_per_slot, page_size), so the
# serving engine's single-trace contract survives joins and leaves.
# ---------------------------------------------------------------------------
def _decode_attn_rows(q, k, v, mask, f32_math: bool = True):
    """Single-token attention with a per-row key mask.

    q (B, 1, H, hd); k/v (B, K, Hkv, hd); mask (B, K) bool — True where
    row b may attend to key slot j.  The caller guarantees every row has
    at least one True (inactive rows point at one masked-garbage slot so
    the softmax never sees an all ``-inf`` row).
    """
    B, _, H, hd = q.shape
    out_dtype = q.dtype
    Hkv = k.shape[2]
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    k = shard(k, "batch", None, "model", None)
    v = shard(v, "batch", None, "model", None)
    scale = 1.0 / np.sqrt(hd)
    if f32_math:
        q, k = q.astype(jnp.float32), k.astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    if f32_math:
        v = v.astype(jnp.float32)
    else:
        probs = probs.astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v,
                     preferred_element_type=jnp.float32)
    out = shard(out, "batch", None, "model", None)
    return out.astype(out_dtype)


def _rows_rope(x, pos, head_dim, theta):
    """Per-row rope for single-token decode: x (B, 1, H, hd), pos (B,)."""
    cos, sin = rope_tables(pos, head_dim, theta)      # (B, hd//2)
    return apply_rope(x, cos[:, None], sin[:, None])


def attention_decode_ring(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                  # (B, 1, D)
    cache: dict[str, jax.Array],   # {"k","v"}: (B, C, Hkv, hd) per-slot ring
    pos: jax.Array,                # (B,) int32 — per-slot absolute position
    active: jax.Array,             # (B,) bool
    window: int,                   # ring capacity == attention window
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Sliding-window decode with *per-row* positions.  The ring layout
    is unchanged from :func:`attention_decode` (slot ``pos % C`` holds the
    newest token); only the position arithmetic became row-wise.  A slot
    whose occupant just joined at ``pos=0`` masks out every stale ring
    entry the previous occupant left behind — validity is derived from
    ``pos``, never from what the buffer happens to contain."""
    B = x.shape[0]
    q, k_new, v_new = _qkv(cfg, p, x)
    q = _rows_rope(q, pos, cfg.hd, cfg.rope_theta)
    k_new = _rows_rope(k_new, pos, cfg.hd, cfg.rope_theta)

    C = cache["k"].shape[1]
    slot = pos % C
    rows = jnp.arange(B)
    k = cache["k"].at[rows, slot].set(k_new[:, 0])
    v = cache["v"].at[rows, slot].set(v_new[:, 0])
    new_cache = {"k": k, "v": v}

    idx = jnp.arange(C)[None, :]                       # (1, C)
    pos_c, slot_c = pos[:, None], slot[:, None]
    # ring slot i holds absolute position in (pos - C, pos]
    k_pos = jnp.where(idx <= slot_c, pos_c - slot_c + idx,
                      pos_c - slot_c - C + idx)
    mask = (k_pos >= 0) & (k_pos > pos_c - C - 1)
    # inactive rows attend to exactly slot 0 (output discarded, but the
    # softmax must not see an empty row)
    mask = jnp.where(active[:, None], mask, idx == 0)
    out = _decode_attn_rows(q, k, v, mask, f32_math=cfg.attn_f32)
    out = linear(out.reshape(B, 1, -1), p["wo"])
    return out, new_cache


def _paged_write(pool: jax.Array, new_row: jax.Array, pos: jax.Array,
                 tables: jax.Array) -> jax.Array:
    """Scatter one new per-slot row into the shared page pool.

    pool (P+1, page_size, ...); new_row (B, ...); pos (B,); tables
    (B, T) physical page ids.  Inactive slots carry all-scratch tables
    and ``pos=0``, so their writes land on the reserved scratch page —
    duplicate scratch writes race benignly (nobody reads it unmasked).
    """
    page_size = pool.shape[1]
    page = jnp.take_along_axis(tables, (pos // page_size)[:, None],
                               axis=1)[:, 0]
    return pool.at[page, pos % page_size].set(new_row)


def _paged_read(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """Gather each slot's pages back into a contiguous per-slot view:
    pool (P+1, page_size, ...) + tables (B, T) -> (B, T*page_size, ...)."""
    B, T = tables.shape
    v = pool[tables]                                  # (B, T, page_size, ...)
    return v.reshape((B, T * pool.shape[1]) + pool.shape[2:])


def attention_decode_paged(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                  # (B, 1, D)
    cache: dict[str, jax.Array],   # {"kp","vp"}: (P+1, page_size, Hkv, hd)
    pos: jax.Array,                # (B,) int32
    tables: jax.Array,             # (B, T) int32 physical page ids
    active: jax.Array,             # (B,) bool
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Global-attention decode over the shared page pool.  Each slot
    writes its new KV at ``table[pos // page_size], pos % page_size`` and
    attends over the gathered view of its own pages; positions a request
    has not written yet (stale KV from a freed request included) are
    masked by ``j <= pos``, so page *reuse* needs no zeroing."""
    B = x.shape[0]
    q, k_new, v_new = _qkv(cfg, p, x)
    q = _rows_rope(q, pos, cfg.hd, cfg.rope_theta)
    k_new = _rows_rope(k_new, pos, cfg.hd, cfg.rope_theta)

    kp = _paged_write(cache["kp"], k_new[:, 0], pos, tables)
    vp = _paged_write(cache["vp"], v_new[:, 0], pos, tables)
    new_cache = {"kp": kp, "vp": vp}

    k = _paged_read(kp, tables)                       # (B, K, Hkv, hd)
    v = _paged_read(vp, tables)
    idx = jnp.arange(k.shape[1])[None, :]             # logical positions
    mask = idx <= pos[:, None]
    mask = jnp.where(active[:, None], mask, idx == 0)
    out = _decode_attn_rows(q, k, v, mask, f32_math=cfg.attn_f32)
    out = linear(out.reshape(B, 1, -1), p["wo"])
    return out, new_cache


def mla_attention_decode_paged(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                  # (B, 1, D)
    cache: dict[str, jax.Array],   # {"ckvp": (P+1, S, R), "krp": (P+1, S, rd)}
    pos: jax.Array,                # (B,) int32
    tables: jax.Array,             # (B, T) int32
    active: jax.Array,             # (B,) bool
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Absorbed-matrix MLA decode over a paged compressed cache — the
    same serving trick as :func:`mla_attention_decode`, with the
    ``(B, C, R)`` dense cache replaced by a shared page pool."""
    mla = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    nope, rope_d, vd, R = (
        mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim,
        mla.kv_lora_rank,
    )
    q = linear(x, p["wq"]).reshape(B, 1, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_tables(pos, rope_d, cfg.rope_theta)   # (B, rd//2)
    q_rope = apply_rope(q_rope, cos[:, None], sin[:, None])

    ckv_kr = linear(x, p["wdkv"])
    c_new, kr_new = ckv_kr[..., :R], ckv_kr[..., R:]
    kr_new = apply_rope(kr_new[:, :, None, :], cos[:, None],
                        sin[:, None])[:, :, 0]
    ckvp = _paged_write(cache["ckvp"], c_new[:, 0], pos, tables)
    krp = _paged_write(cache["krp"], kr_new[:, 0], pos, tables)
    new_cache = {"ckvp": ckvp, "krp": krp}

    ckv = _paged_read(ckvp, tables)                   # (B, K, R)
    kr = _paged_read(krp, tables)                     # (B, K, rope_d)
    wuk = p["wuk"].reshape(R, H, nope)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       wuk.astype(jnp.float32))
    scale = 1.0 / np.sqrt(nope + rope_d)
    logits = (
        jnp.einsum("bhr,bcr->bhc", q_abs, ckv.astype(jnp.float32))
        + jnp.einsum("bhd,bcd->bhc", q_rope[:, 0].astype(jnp.float32),
                     kr.astype(jnp.float32))
    ) * scale
    idx = jnp.arange(ckv.shape[1])[None, :]
    mask = idx <= pos[:, None]
    mask = jnp.where(active[:, None], mask, idx == 0)
    logits = jnp.where(mask[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhc,bcr->bhr", probs, ckv.astype(jnp.float32))
    wuv = p["wuv"].reshape(R, H, vd)
    out = jnp.einsum("bhr,rhv->bhv", ctx, wuv.astype(jnp.float32))
    out = out.reshape(B, 1, H * vd).astype(x.dtype)
    return linear(out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# dense FFN (SwiGLU / GELU)
# ---------------------------------------------------------------------------
def init_ffn(cfg: ModelConfig, key, *, gelu: bool = False, d_ff: int | None = None) -> Params:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.jnp_dtype
    ks = _keys(key, 3)
    if gelu:
        return {"w1": _dense_init(ks[0], (D, F), dt), "w2": _dense_init(ks[1], (F, D), dt)}
    return {
        "w1": _dense_init(ks[0], (D, F), dt),
        "w3": _dense_init(ks[1], (D, F), dt),
        "w2": _dense_init(ks[2], (F, D), dt),
    }


def ffn(cfg: ModelConfig, p: Params, x: jax.Array, lut=None) -> jax.Array:
    if "w3" in p:
        h = jax.nn.silu(linear(x, p["w1"], lut)) * linear(x, p["w3"], lut)
    else:
        h = jax.nn.gelu(linear(x, p["w1"], lut))
    h = shard(h, "batch", None, "model")
    return linear(h, p["w2"], lut)


# ---------------------------------------------------------------------------
# MoE FFN: sort-based dispatch + ragged_dot (exact active FLOPs)
# ---------------------------------------------------------------------------
def init_moe(cfg: ModelConfig, key) -> Params:
    mo = cfg.moe
    D, Fe, E = cfg.d_model, mo.d_ff_expert, mo.n_experts
    dt = cfg.jnp_dtype
    ks = _keys(key, 5)
    p = {
        "router": _dense_init(ks[0], (D, E), jnp.float32),
        "w1": _dense_init(ks[1], (E, D, Fe), dt, fan_in=D),
        "w3": _dense_init(ks[2], (E, D, Fe), dt, fan_in=D),
        "w2": _dense_init(ks[3], (E, Fe, D), dt, fan_in=Fe),
    }
    if mo.n_shared:
        sub = jax.random.split(ks[4], mo.n_shared)
        p["shared"] = [
            init_ffn(cfg, sub[i], d_ff=mo.d_ff_expert) for i in range(mo.n_shared)
        ]
    return p


def moe_ffn(cfg: ModelConfig, p: Params, x: jax.Array, lut=None,
            dropless: bool = False):
    """Returns (out, aux_loss).  Two dispatch implementations:

    * ``blocked`` (default): sort tokens by expert, pack each expert's
      tokens into a fixed-capacity block (megablocks-lite), run the expert
      stack as *batched matmuls* ``(E, C, D) x (E, D, F)``.  FLOPs =
      capacity_factor x active FLOPs in BOTH forward and backward, and the
      batched-matmul VJP partitions cleanly under GSPMD.  Overflow tokens
      beyond capacity are dropped (standard GShard/Switch semantics).
    * ``ragged``: dropless ``lax.ragged_dot``.  Exact, but its XLA
      lowering (and its VJP in particular) densifies to all-experts
      compute on non-Mosaic backends — E x overcompute (measured 8x fwd /
      8x bwd for E=8; see EXPERIMENTS.md §Perf iteration 1).
    """
    mo = cfg.moe
    B, S, D = x.shape
    T, K, E = B * S, mo.top_k, mo.n_experts
    flat = x.reshape(T, D)

    logits = linear(flat.astype(jnp.float32), p["router"])   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)                     # (T, K)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * K)
    aux = mo.aux_loss_weight * E * jnp.sum(me * ce)

    flat_e = idx.reshape(-1)                                 # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e)
    group_sizes = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)

    if mo.impl == "ragged":
        xs = flat[flat_t[order]]                             # (T*K, D)
        h1 = jax.lax.ragged_dot(xs, p["w1"], group_sizes)
        h3 = jax.lax.ragged_dot(xs, p["w3"], group_sizes)
        hs = jax.nn.silu(h1) * h3
        hs = shard(hs, "batch", "model")
        ys = jax.lax.ragged_dot(hs, p["w2"], group_sizes)    # (T*K, D)
        out = jnp.zeros((T, D), jnp.float32)
        out = out.at[flat_t[order]].add(
            ys.astype(jnp.float32) * flat_g[order][:, None])
    else:
        if dropless:
            # decode: per-step token counts are tiny and token dropping
            # would break decode == teacher-forced-forward; worst case all
            # tokens route to one expert -> capacity T*K is exact
            C = T * K
        else:
            C = max(1, int(np.ceil(T * K / E * mo.capacity_factor)))
        starts = jnp.cumsum(group_sizes) - group_sizes       # (E,)
        slot_c = jax.lax.broadcasted_iota(jnp.int32, (E, C), 1)
        src = starts[:, None] + slot_c                       # (E, C) into order
        valid = slot_c < group_sizes[:, None]
        src = jnp.minimum(src, T * K - 1)
        rows = flat_t[order][src]                            # (E, C) token ids
        g_blk = jnp.where(valid, flat_g[order][src], 0.0)    # (E, C)
        xs = flat[rows] * valid[..., None].astype(flat.dtype)  # (E, C, D)
        # expert parallelism when E divides the data axis (the classic MoE
        # all-to-all appears at the gather/scatter boundary); otherwise the
        # capacity axis stays data-parallel and expert weights stay FSDP
        ep = E % max(1, axis_extent("expert")) == 0 and axis_extent("expert") > 1
        if ep:
            xs = shard(xs, "expert", None, None)
        else:
            xs = shard(xs, None, "batch", None)
        h1 = jnp.einsum("ecd,edf->ecf", xs, p["w1"])
        h3 = jnp.einsum("ecd,edf->ecf", xs, p["w3"])
        hs = jax.nn.silu(h1) * h3
        hs = shard(hs, "expert" if ep else None, None if ep else "batch", "model")
        ys = jnp.einsum("ecf,efd->ecd", hs, p["w2"])         # (E, C, D)
        out = jnp.zeros((T, D), jnp.float32)
        out = out.at[rows.reshape(-1)].add(
            (ys * g_blk[..., None]).reshape(-1, D).astype(jnp.float32))

    out = out.reshape(B, S, D).astype(x.dtype)
    for sp in p.get("shared", []):
        out = out + ffn(cfg, sp, x, lut)
    return out, aux


# ---------------------------------------------------------------------------
# RWKV6 time-mix / channel-mix (Finch: data-dependent decay)
# ---------------------------------------------------------------------------
def init_rwkv(cfg: ModelConfig, key) -> Params:
    rw = cfg.rwkv
    D = cfg.d_model
    hd = rw.head_dim
    H = D // hd
    dt = cfg.jnp_dtype
    ks = _keys(key, 10)
    return {
        "mix_r": jnp.full((D,), 0.5, dt), "mix_k": jnp.full((D,), 0.5, dt),
        "mix_v": jnp.full((D,), 0.5, dt), "mix_g": jnp.full((D,), 0.5, dt),
        "mix_w": jnp.full((D,), 0.5, dt),
        "wr": _dense_init(ks[0], (D, D), dt), "wk": _dense_init(ks[1], (D, D), dt),
        "wv": _dense_init(ks[2], (D, D), dt), "wg": _dense_init(ks[3], (D, D), dt),
        "wo": _dense_init(ks[4], (D, D), dt),
        "w0": jnp.full((D,), -6.0, jnp.float32),
        "w_a": _dense_init(ks[5], (D, rw.decay_lora), dt),
        "w_b": _dense_init(ks[6], (rw.decay_lora, D), dt),
        "u": jnp.zeros((H, hd), jnp.float32),
        "ln_x": jnp.zeros((D,), dt),
        # channel mix
        "cmix_k": jnp.full((D,), 0.5, dt), "cmix_r": jnp.full((D,), 0.5, dt),
        "ck": _dense_init(ks[7], (D, cfg.d_ff), dt),
        "cv": _dense_init(ks[8], (cfg.d_ff, D), dt),
        "cr": _dense_init(ks[9], (D, D), dt),
    }


def _rwkv_wkv_scan(r, k, v, w, u, state0):
    """r/k/v (B,S,H,hd) f32; w (B,S,H,hd) decay in (0,1); u (H,hd).

    state (B,H,hd,hd):  y_t = r_t · (state + u⊙k_t ⊗ v_t);
                        state' = w_t⊙state + k_t ⊗ v_t  (⊙ along the k-index)
    """
    def step(state, inp):
        rt, kt, vt, wt = inp  # (B,H,hd) each
        kv = jnp.einsum("bhj,bhi->bhji", kt, vt)             # (B,H,hd,hd)
        y = jnp.einsum("bhj,bhji->bhi", rt, state + u[None, :, :, None] * kv)
        state = wt[..., None] * state + kv
        return state, y

    rs, ks_, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))  # (S,B,H,hd)
    state, ys = jax.lax.scan(step, state0, (rs, ks_, vs, ws))
    return jnp.moveaxis(ys, 0, 1), state                      # (B,S,H,hd)


def rwkv_time_mix(cfg: ModelConfig, p: Params, x: jax.Array,
                  state: tuple | None = None):
    """Returns (out, (x_last, wkv_state)).  ``state=None`` => zeros (train);
    decode passes the carried state and S == 1."""
    rw = cfg.rwkv
    B, S, D = x.shape
    hd = rw.head_dim
    H = D // hd
    if state is None:
        x_prev_last = jnp.zeros((B, 1, D), x.dtype)
        wkv0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    else:
        x_prev_last, wkv0 = state
    xprev = jnp.concatenate([x_prev_last, x[:, :-1]], axis=1)

    def mixed(mu):
        return x + (xprev - x) * mu

    r = linear(mixed(p["mix_r"]), p["wr"]).reshape(B, S, H, hd).astype(jnp.float32)
    k = linear(mixed(p["mix_k"]), p["wk"]).reshape(B, S, H, hd).astype(jnp.float32)
    v = linear(mixed(p["mix_v"]), p["wv"]).reshape(B, S, H, hd).astype(jnp.float32)
    g = jax.nn.silu(linear(mixed(p["mix_g"]), p["wg"]))
    xw = mixed(p["mix_w"])
    dd = linear(jnp.tanh(linear(xw, p["w_a"])), p["w_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["w0"][None, None] + dd))          # (B,S,D) in (0,1)
    w = w.reshape(B, S, H, hd)

    y, wkv = _rwkv_wkv_scan(r, k, v, w, p["u"], wkv0)
    y = rmsnorm(y.reshape(B, S, D).astype(x.dtype), p["ln_x"], cfg.norm_eps)
    out = linear(y * g, p["wo"])
    return out, (x[:, -1:], wkv)


def rwkv_channel_mix(cfg: ModelConfig, p: Params, x: jax.Array,
                     x_last: jax.Array | None = None):
    B, S, D = x.shape
    if x_last is None:
        x_last = jnp.zeros((B, 1, D), x.dtype)
    xprev = jnp.concatenate([x_last, x[:, :-1]], axis=1)
    xk = x + (xprev - x) * p["cmix_k"]
    xr = x + (xprev - x) * p["cmix_r"]
    k = jnp.square(jax.nn.relu(linear(xk, p["ck"])))
    out = jax.nn.sigmoid(linear(xr, p["cr"])) * linear(k, p["cv"])
    return out, x[:, -1:]


# ---------------------------------------------------------------------------
# SSM mixer (Mamba-style selective scan; hymba's parallel heads)
# ---------------------------------------------------------------------------
def init_ssm(cfg: ModelConfig, key) -> Params:
    sm = cfg.ssm
    D = cfg.d_model
    Di = sm.d_inner or D
    N = sm.state_dim
    dt = cfg.jnp_dtype
    ks = _keys(key, 6)
    return {
        "win": _dense_init(ks[0], (D, 2 * Di), dt),
        "wB": _dense_init(ks[1], (Di, N), dt),
        "wC": _dense_init(ks[2], (Di, N), dt),
        "wdt1": _dense_init(ks[3], (Di, sm.dt_rank), dt),
        "wdt2": _dense_init(ks[4], (sm.dt_rank, Di), dt),
        "A_log": jnp.zeros((Di, N), jnp.float32),
        "Dskip": jnp.ones((Di,), jnp.float32),
        "wout": _dense_init(ks[5], (Di, D), dt),
    }


def ssm_mix(cfg: ModelConfig, p: Params, x: jax.Array,
            state: jax.Array | None = None):
    """Selective scan.  Returns (out, state).  state (B, Di, N)."""
    sm = cfg.ssm
    B, S, D = x.shape
    Di = sm.d_inner or D
    N = sm.state_dim
    xz = linear(x, p["win"])
    xi, z = xz[..., :Di], xz[..., Di:]
    xi_f = xi.astype(jnp.float32)
    dt = jax.nn.softplus(linear(jnp.einsum("bsd,dr->bsr", xi, p["wdt1"]),
                                p["wdt2"]).astype(jnp.float32))   # (B,S,Di)
    Bt = linear(xi, p["wB"]).astype(jnp.float32)                   # (B,S,N)
    Ct = linear(xi, p["wC"]).astype(jnp.float32)                   # (B,S,N)
    A = -jnp.exp(p["A_log"])                                       # (Di,N)
    decay = jnp.exp(dt[..., None] * A[None, None])                 # (B,S,Di,N)
    contrib = (dt * xi_f)[..., None] * Bt[:, :, None, :]           # (B,S,Di,N)

    if state is None:
        state = jnp.zeros((B, Di, N), jnp.float32)

    def step(s, inp):
        d, c, ct = inp                                             # (B,Di,N)x2,(B,N)
        s = d * s + c
        y = jnp.einsum("bdn,bn->bd", s, ct)
        return s, y

    ds, cs, cts = (jnp.moveaxis(t, 1, 0) for t in (decay, contrib, Ct))
    state, ys = jax.lax.scan(step, state, (ds, cs, cts))
    y = jnp.moveaxis(ys, 0, 1) + p["Dskip"][None, None] * xi_f     # (B,S,Di)
    out = linear(y.astype(x.dtype) * jax.nn.silu(z), p["wout"])
    return out, state
