"""Decoder-only language model: init / forward / decode for every family.

Design notes (DESIGN.md §5, §7):

* **Scan-over-layers** for train/prefill: per-layer params are stacked on a
  leading axis and the block body is traced once — HLO size is O(1) in
  depth, which matters both for the 1-core CPU here and for real compile
  times at 1000+ nodes.  Per-layer heterogeneity (gemma3 local/global,
  hymba's periodic global layers) rides through the scan as a traced
  per-layer window scalar (``-1`` = global).
* **Python loop over layers** for decode: caches are *heterogeneous*
  (ring buffers for sliding-window layers, full-length for global layers,
  recurrent states for SSM/RWKV), so each layer owns its own cache pytree
  and the loop unrolls — decode graphs are small.
* MoE aux (load-balance) losses accumulate through the scan carry.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel import shard
from .config import ModelConfig
from . import layers as L

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# per-layer window schedule
# ---------------------------------------------------------------------------
def window_schedule(cfg: ModelConfig) -> np.ndarray | int | None:
    """None = all-global; int = uniform window; array (L,) = per-layer
    (-1 marks a global layer)."""
    if cfg.local_global_every is not None:
        win = np.full((cfg.n_layers,), cfg.local_window, dtype=np.int32)
        win[cfg.local_global_every - 1 :: cfg.local_global_every] = -1
        return win
    if cfg.sliding_window is not None:
        return int(cfg.sliding_window)
    return None


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_layer(cfg: ModelConfig, key) -> Params:
    ks = list(jax.random.split(key, 4))
    dt = cfg.jnp_dtype
    p: Params = {"ln1": jnp.zeros((cfg.d_model,), dt), "ln2": jnp.zeros((cfg.d_model,), dt)}
    if cfg.rwkv is not None:
        p["rwkv"] = L.init_rwkv(cfg, ks[0])
        return p
    if cfg.mla is not None:
        p["attn"] = L.init_mla(cfg, ks[0])
    else:
        p["attn"] = L.init_attention(cfg, ks[0])
    if cfg.ssm is not None:
        p["ssm"] = L.init_ssm(cfg, ks[1])
    if cfg.moe is not None:
        p["moe"] = L.init_moe(cfg, ks[2])
    else:
        p["ffn"] = L.init_ffn(cfg, ks[3])
    return p


def init_lm(cfg: ModelConfig, key) -> Params:
    ks = list(jax.random.split(key, cfg.n_layers + 3))
    dt = cfg.jnp_dtype
    per_layer = [_init_layer(cfg, ks[i]) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    params: Params = {
        "embed": L._dense_init(ks[-1], (cfg.vocab_size, cfg.d_model), dt, fan_in=cfg.d_model),
        "layers": stacked,
        "ln_f": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(ks[-2], (cfg.d_model, cfg.vocab_size), dt)
    if cfg.vision is not None:
        params["vis_proj"] = L._dense_init(
            ks[-3], (cfg.vision.d_vision, cfg.d_model), dt
        )
    return params


# ---------------------------------------------------------------------------
# one transformer block (full-sequence mode)
# ---------------------------------------------------------------------------
def _block_full(cfg: ModelConfig, lp: Params, x, window, lut, backend):
    if cfg.rwkv is not None:
        h, _ = L.rwkv_time_mix(cfg, lp["rwkv"], L.rmsnorm(x, lp["ln1"], cfg.norm_eps))
        x = x + h
        h, _ = L.rwkv_channel_mix(cfg, lp["rwkv"], L.rmsnorm(x, lp["ln2"], cfg.norm_eps))
        return x + h, jnp.float32(0.0)

    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        attn_out = L.mla_attention_full(cfg, lp["attn"], h)
    else:
        attn_out = L.attention_full(cfg, lp["attn"], h, window, backend=backend)
    if cfg.ssm is not None:  # hybrid: parallel SSM head fused with attention
        ssm_out, _ = L.ssm_mix(cfg, lp["ssm"], h)
        attn_out = 0.5 * (attn_out + ssm_out)
    x = x + attn_out

    h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        mlp_out, aux = L.moe_ffn(cfg, lp["moe"], h, lut)
    else:
        mlp_out, aux = L.ffn(cfg, lp["ffn"], h, lut), jnp.float32(0.0)
    return x + mlp_out, aux


def forward_lm(
    cfg: ModelConfig,
    params: Params,
    batch: dict[str, jax.Array],
    *,
    lut: jax.Array | None = None,
    backend: str = "auto",
    remat: str = "none",
    scan_unroll: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Teacher-forced forward.  Returns (logits (B, S_total, V), aux_loss).

    ``batch['tokens']``: (B, S) int32.  VLM batches add ``'patches'``
    (B, P, d_vision) which are projected and prepended.
    ``lut``: optional approximate-multiplier table — either one
    (side, side) table shared by every layer, or a per-layer
    (n_layers, side, side) stack (a QoS
    :class:`~repro.library.qos.LayerPlan`), which rides through the layer
    scan alongside the stacked params; side = 16 (W4A4) or 256 (W8A8).
    ``scan_unroll``: unroll the layer scan — used by the roofline analysis
    (XLA cost_analysis counts a rolled scan body once; see dryrun.py).
    """
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(cfg.jnp_dtype)
    if cfg.vision is not None:
        pv = jnp.einsum("bpd,dm->bpm", batch["patches"].astype(cfg.jnp_dtype),
                        params["vis_proj"])
        x = jnp.concatenate([pv, x], axis=1)
    x = shard(x, "batch", None, None)

    win = window_schedule(cfg)
    lut_ = lut if cfg.approx_mlp else None
    per_layer_lut = lut_ is not None and jnp.ndim(lut_) == 3

    def body(carry, scanned):
        x, aux = carry
        lp = scanned["lp"]
        w = scanned["win"] if isinstance(win, np.ndarray) else win
        l = scanned["lut"] if per_layer_lut else lut_
        x, aux_i = _block_full(cfg, lp, x, w, l, backend)
        x = shard(x, "batch", None, None)
        return (x, aux + aux_i), None

    if remat != "none":
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if remat == "dots"
            else jax.checkpoint_policies.nothing_saveable
        )
        body = jax.checkpoint(body, policy=policy)

    xs: dict = {"lp": params["layers"]}
    if isinstance(win, np.ndarray):
        xs["win"] = jnp.asarray(win)
    if per_layer_lut:
        xs["lut"] = jnp.asarray(lut_)
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.float32(0.0)), xs, unroll=True if scan_unroll else 1
    )

    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    logits = shard(logits, "batch", None, "model")
    return logits, aux


def lm_loss(cfg, params, batch, *, lut=None, backend="auto", remat="none",
            scan_unroll=False):
    """Next-token cross-entropy (text positions only for VLM)."""
    logits, aux = forward_lm(cfg, params, batch, lut=lut, backend=backend,
                             remat=remat, scan_unroll=scan_unroll)
    tokens = batch["tokens"]
    n_prefix = cfg.vision.n_patches if cfg.vision is not None else 0
    logits_text = logits[:, n_prefix:, :]
    pred = logits_text[:, :-1]
    tgt = tokens[:, 1:]
    logp = jax.nn.log_softmax(pred, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean() + aux


# ---------------------------------------------------------------------------
# decode: heterogeneous per-layer caches, Python loop over layers
# ---------------------------------------------------------------------------
def init_decode_caches(cfg: ModelConfig, batch: int, seq_len: int) -> list[Params]:
    """One cache pytree per layer, sized by that layer's attention kind."""
    win = window_schedule(cfg)
    dt = cfg.jnp_dtype
    caches: list[Params] = []
    for layer in range(cfg.n_layers):
        c: Params = {}
        if cfg.rwkv is not None:
            rw = cfg.rwkv
            H = cfg.d_model // rw.head_dim
            c["x_tm"] = jnp.zeros((batch, 1, cfg.d_model), dt)
            c["x_cm"] = jnp.zeros((batch, 1, cfg.d_model), dt)
            c["wkv"] = jnp.zeros((batch, H, rw.head_dim, rw.head_dim), jnp.float32)
            caches.append(c)
            continue
        if cfg.mla is not None:
            mla = cfg.mla
            c["ckv"] = jnp.zeros((batch, seq_len, mla.kv_lora_rank), dt)
            c["kr"] = jnp.zeros((batch, seq_len, mla.qk_rope_head_dim), dt)
        else:
            if isinstance(win, np.ndarray):
                w = int(win[layer])
                slots = seq_len if w < 0 else min(w, seq_len)
            elif isinstance(win, int):
                slots = min(win, seq_len)
            else:
                slots = seq_len
            c["k"] = jnp.zeros((batch, slots, cfg.n_kv_heads, cfg.hd), dt)
            c["v"] = jnp.zeros((batch, slots, cfg.n_kv_heads, cfg.hd), dt)
        if cfg.ssm is not None:
            sm = cfg.ssm
            di = sm.d_inner or cfg.d_model
            c["ssm"] = jnp.zeros((batch, di, sm.state_dim), jnp.float32)
        caches.append(c)
    return caches


def init_paged_caches(cfg: ModelConfig, batch: int, n_pages: int,
                      page_size: int, max_len: int) -> list[Params]:
    """Decode caches for continuous batching: global-attention layers
    share one ``(n_pages + 1, page_size, ...)`` page *pool* (physical
    page 0 is the allocator's scratch page), sliding-window layers keep a
    small per-slot ring (their cache is already bounded by the window —
    paging it would buy nothing), and ``max_len`` bounds the per-request
    page-table width.  Recurrent state (RWKV / SSM) cannot be paged or
    resumed from KV alone, so those families are rejected here rather
    than silently served wrong."""
    if cfg.rwkv is not None or cfg.ssm is not None:
        raise ValueError(
            f"{cfg.name}: continuous batching pages KV caches; recurrent "
            f"state (rwkv/ssm) has no positional cache to page — use the "
            f"fixed-batch engine for this family")
    win = window_schedule(cfg)
    dt = cfg.jnp_dtype
    caches: list[Params] = []
    for layer in range(cfg.n_layers):
        c: Params = {}
        if cfg.mla is not None:
            mla = cfg.mla
            c["ckvp"] = jnp.zeros((n_pages + 1, page_size,
                                   mla.kv_lora_rank), dt)
            c["krp"] = jnp.zeros((n_pages + 1, page_size,
                                  mla.qk_rope_head_dim), dt)
        else:
            w = (int(win[layer]) if isinstance(win, np.ndarray)
                 else win if isinstance(win, int) else -1)
            if w is not None and w > 0:
                slots = min(w, max_len)
                c["k"] = jnp.zeros((batch, slots, cfg.n_kv_heads, cfg.hd), dt)
                c["v"] = jnp.zeros((batch, slots, cfg.n_kv_heads, cfg.hd), dt)
            else:
                c["kp"] = jnp.zeros((n_pages + 1, page_size,
                                     cfg.n_kv_heads, cfg.hd), dt)
                c["vp"] = jnp.zeros((n_pages + 1, page_size,
                                     cfg.n_kv_heads, cfg.hd), dt)
        caches.append(c)
    return caches


def shard_decode_caches(caches: list[Params], cfg: ModelConfig) -> list[Params]:
    """Apply logical sharding to caches: batch over data when divisible,
    else context-parallel over the cache-sequence axis (long_500k, B=1)."""
    out = []
    for c in caches:
        sc = dict(c)
        for name in ("k", "v"):
            if name in sc:
                sc[name] = shard(sc[name], "batch", "cache_seq", "model", None)
        if "ckv" in sc:
            sc["ckv"] = shard(sc["ckv"], "batch", "cache_seq", "model")
            sc["kr"] = shard(sc["kr"], "batch", "cache_seq", None)
        if "ssm" in sc:
            sc["ssm"] = shard(sc["ssm"], "batch", "model", None)
        if "wkv" in sc:
            sc["wkv"] = shard(sc["wkv"], "batch", "model", None, None)
        out.append(sc)
    return out


def _block_decode(cfg: ModelConfig, lp: Params, x, cache: Params, pos, window,
                  lut=None):
    new_cache = dict(cache)
    if cfg.rwkv is not None:
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        h_in = jnp.concatenate([cache["x_tm"], h], axis=1)  # token-shift via state
        out, (x_tm, wkv) = L.rwkv_time_mix(
            cfg, lp["rwkv"], h, state=(cache["x_tm"], cache["wkv"])
        )
        x = x + out
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        out, x_cm = L.rwkv_channel_mix(cfg, lp["rwkv"], h, x_last=cache["x_cm"])
        new_cache.update(x_tm=x_tm, wkv=wkv, x_cm=x_cm)
        return x + out, new_cache

    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        attn_out, upd = L.mla_attention_decode(cfg, lp["attn"], h, cache, pos)
    else:
        attn_out, upd = L.attention_decode(cfg, lp["attn"], h, cache, pos, window)
    new_cache.update(upd)
    if cfg.ssm is not None:
        ssm_out, s = L.ssm_mix(cfg, lp["ssm"], h, state=cache["ssm"])
        new_cache["ssm"] = s
        attn_out = 0.5 * (attn_out + ssm_out)
    x = x + attn_out

    h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        mlp_out, _ = L.moe_ffn(cfg, lp["moe"], h, lut, dropless=True)
    else:
        mlp_out = L.ffn(cfg, lp["ffn"], h, lut)
    return x + mlp_out, new_cache


def decode_step(
    cfg: ModelConfig,
    params: Params,
    caches: list[Params],
    tokens: jax.Array,   # (B, 1) int32 — the newest token
    pos: jax.Array,      # () int32 — its absolute position
    *,
    luts: jax.Array | dict[int, jax.Array] | None = None,
    #     (L, side, side) per-layer LUTs, (side, side) shared, or a
    #     mixed-width dict {bits: (n_group, side, side)} — side = 16
    #     (W4A4) or 256 (composed W8A8 tables)
    width_map: tuple[int, ...] | None = None,
) -> tuple[jax.Array, list[Params]]:
    """One serving step: append token at ``pos``, return next-token logits.

    ``luts``: optional approximate-multiplier tables routing each layer's
    MLP matmuls (QoS plan); the decode loop is unrolled per layer, so the
    per-layer table is just indexed out.  The table side picks the
    operand width (``repro.quant.approx_linear`` infers bias and code
    range from it), so the same decode step serves W4A4 ``(L, 16, 16)``
    and W8A8 ``(L, 256, 256)`` stacks — at a *fixed* width per trace:
    shapes are jit-static, so width moves recompile while same-width plan
    swaps never do.

    Mixed-width serving passes ``luts`` as a dict holding one stack per
    width group plus a static ``width_map`` (one entry per layer): layer
    ``i`` reads table ``luts[width_map[i]]`` at its position within its
    group (layer order within the group).  The width map is part of the
    traced python structure, so it is frozen per trace — same-map plan
    swaps re-stack the group arrays and reuse the one executable, exactly
    like the single-width case.

    ``luts`` must ride through ``jax.jit`` as a *real argument* (a jax
    array / tracer pytree), never a closed-over host constant: the
    adaptive serving runtime (:mod:`repro.serving`) hot-swaps plans
    between batches by passing a different stack to the same traced
    executable, which only works if tracing never baked the table in.
    """
    win = window_schedule(cfg)
    luts_ = luts if cfg.approx_mlp else None
    leaves = luts_.values() if isinstance(luts_, dict) else (luts_,)
    if any(isinstance(v, np.ndarray) for v in leaves):
        # a host numpy table would be traced as a compile-time constant and
        # every plan swap would silently rebuild the executable
        raise TypeError(
            "decode_step luts must be a jax array passed as a jit argument, "
            "not a numpy constant (serving hot-swap relies on this)"
        )
    group_pos: list[int] | None = None
    if isinstance(luts_, dict):
        if width_map is None or len(width_map) != cfg.n_layers:
            raise ValueError(
                f"a mixed-width luts dict needs a width_map with one entry "
                f"per layer (got {width_map!r} for {cfg.n_layers} layers)"
            )
        # layer i's row within its width group = how many earlier layers
        # share its width (group stacks are packed in layer order)
        group_pos = [width_map[:i].count(width_map[i])
                     for i in range(cfg.n_layers)]
    x = params["embed"][tokens].astype(cfg.jnp_dtype)
    x = shard(x, "batch", None, None)
    new_caches: list[Params] = []
    layer_params = [
        jax.tree.map(lambda a, i=i: a[i], params["layers"])
        for i in range(cfg.n_layers)
    ]
    for i, (lp, cache) in enumerate(zip(layer_params, caches)):
        if isinstance(win, np.ndarray):
            w = int(win[i])
            w = None if w < 0 else w
        else:
            w = win
        lut_i = None
        if isinstance(luts_, dict):
            lut_i = luts_[width_map[i]][group_pos[i]]
        elif luts_ is not None:
            lut_i = luts_[i] if jnp.ndim(luts_) == 3 else luts_
        x, nc = _block_decode(cfg, lp, x, cache, pos, w, lut_i)
        new_caches.append(nc)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)[:, 0]
    return logits, new_caches


# ---------------------------------------------------------------------------
# paged decode: per-slot positions, shared page pools (continuous batching)
# ---------------------------------------------------------------------------
def _block_decode_paged(cfg: ModelConfig, lp: Params, x, cache: Params,
                        pos, tables, active, window, lut=None):
    new_cache = dict(cache)
    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        attn_out, upd = L.mla_attention_decode_paged(
            cfg, lp["attn"], h, cache, pos, tables, active)
    elif "kp" in cache:
        attn_out, upd = L.attention_decode_paged(
            cfg, lp["attn"], h, cache, pos, tables, active)
    else:
        attn_out, upd = L.attention_decode_ring(
            cfg, lp["attn"], h, cache, pos, active, window)
    new_cache.update(upd)
    x = x + attn_out

    h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        mlp_out, _ = L.moe_ffn(cfg, lp["moe"], h, lut, dropless=True)
    else:
        mlp_out = L.ffn(cfg, lp["ffn"], h, lut)
    return x + mlp_out, new_cache


def decode_step_paged(
    cfg: ModelConfig,
    params: Params,
    caches: list[Params],
    tokens: jax.Array,   # (B, 1) int32 — each slot's newest token
    pos: jax.Array,      # (B,) int32 — per-slot absolute positions
    active: jax.Array,   # (B,) bool — which slots hold a live request
    tables: jax.Array,   # (B, T) int32 — per-slot physical page tables
    *,
    luts: jax.Array | dict[int, jax.Array] | None = None,
    width_map: tuple[int, ...] | None = None,
) -> tuple[jax.Array, list[Params]]:
    """One continuous-batching step: every *active* slot advances one
    token at its own position; inactive slots compute padding rows whose
    cache writes land on the scratch page (page 0) and whose logits the
    host discards.

    This is :func:`decode_step` with the batch-shared scalar ``pos``
    replaced by per-slot vectors and the dense global-attention caches
    replaced by page pools (:func:`init_paged_caches`); the LUT-stack
    contract is identical — ``luts`` rides as a jitted argument (same
    TypeError guard), width maps are trace structure, and all shapes are
    fixed by ``(max_slots, pages_per_slot, page_size)``, so requests
    joining and leaving the running batch never retrace."""
    win = window_schedule(cfg)
    luts_ = luts if cfg.approx_mlp else None
    leaves = luts_.values() if isinstance(luts_, dict) else (luts_,)
    if any(isinstance(v, np.ndarray) for v in leaves):
        raise TypeError(
            "decode_step_paged luts must be a jax array passed as a jit "
            "argument, not a numpy constant (serving hot-swap relies on this)"
        )
    group_pos: list[int] | None = None
    if isinstance(luts_, dict):
        if width_map is None or len(width_map) != cfg.n_layers:
            raise ValueError(
                f"a mixed-width luts dict needs a width_map with one entry "
                f"per layer (got {width_map!r} for {cfg.n_layers} layers)"
            )
        group_pos = [width_map[:i].count(width_map[i])
                     for i in range(cfg.n_layers)]
    x = params["embed"][tokens].astype(cfg.jnp_dtype)
    x = shard(x, "batch", None, None)
    new_caches: list[Params] = []
    layer_params = [
        jax.tree.map(lambda a, i=i: a[i], params["layers"])
        for i in range(cfg.n_layers)
    ]
    for i, (lp, cache) in enumerate(zip(layer_params, caches)):
        if isinstance(win, np.ndarray):
            w = int(win[i])
            w = None if w < 0 else w
        else:
            w = win
        lut_i = None
        if isinstance(luts_, dict):
            lut_i = luts_[width_map[i]][group_pos[i]]
        elif luts_ is not None:
            lut_i = luts_[i] if jnp.ndim(luts_) == 3 else luts_
        x, nc = _block_decode_paged(cfg, lp, x, cache, pos, tables, active,
                                    w, lut_i)
        new_caches.append(nc)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)[:, 0]
    return logits, new_caches
