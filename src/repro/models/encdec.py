"""Whisper-style encoder-decoder (the ``audio`` family).

The conv audio frontend is a stub per the assignment: ``input_specs``
provides precomputed frame embeddings (B, n_frames, d_model).  Positions
are sinusoidal on both sides (the real model's learned decoder positions
cap at 448; our assigned decode shapes go far beyond, so sinusoidal is the
faithful-in-spirit choice — noted in DESIGN.md).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel import shard
from .config import ModelConfig
from . import layers as L

Params = dict[str, Any]


def sinusoid(seq_len: int, d_model: int) -> jax.Array:
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    inv = 1.0 / (10_000 ** (2 * dim / d_model))
    table = np.concatenate([np.sin(pos * inv), np.cos(pos * inv)], axis=-1)
    return jnp.asarray(table, dtype=jnp.float32)


def _init_cross(cfg: ModelConfig, key) -> Params:
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = list(jax.random.split(key, 4))
    dt = cfg.jnp_dtype
    return {
        "wq": L._dense_init(ks[0], (D, H * hd), dt),
        "wk": L._dense_init(ks[1], (D, H * hd), dt),
        "wv": L._dense_init(ks[2], (D, H * hd), dt),
        "wo": L._dense_init(ks[3], (H * hd, D), dt),
    }


def init_encdec(cfg: ModelConfig, key) -> Params:
    enc = cfg.encoder
    ks = list(jax.random.split(key, enc.n_layers + cfg.n_layers + 3))
    dt = cfg.jnp_dtype
    enc_layers = []
    for i in range(enc.n_layers):
        sub = list(jax.random.split(ks[i], 2))
        enc_layers.append({
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "attn": L.init_attention(cfg, sub[0]),
            "ffn": L.init_ffn(cfg, sub[1], gelu=True),
        })
    dec_layers = []
    for i in range(cfg.n_layers):
        sub = list(jax.random.split(ks[enc.n_layers + i], 3))
        dec_layers.append({
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "ln_x": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "attn": L.init_attention(cfg, sub[0]),
            "cross": _init_cross(cfg, sub[1]),
            "ffn": L.init_ffn(cfg, sub[2], gelu=True),
        })
    return {
        "embed": L._dense_init(ks[-1], (cfg.vocab_size, cfg.d_model), dt, fan_in=cfg.d_model),
        "enc_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers),
        "dec_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *dec_layers),
        "ln_enc": jnp.zeros((cfg.d_model,), dt),
        "ln_f": jnp.zeros((cfg.d_model,), dt),
        "lm_head": L._dense_init(ks[-2], (cfg.d_model, cfg.vocab_size), dt),
    }


def _bidir_attention(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    B, S, _ = x.shape
    q, k, v = L._qkv(cfg, p, x)
    pos = jnp.arange(S)
    big = jnp.full((S,), -1, jnp.int32)  # everything visible: use k_pos <= +inf
    out = L._masked_softmax_attn(q, k, v, jnp.full((S,), S, jnp.int32), pos,
                                 None, f32_math=cfg.attn_f32)
    return L.linear(out.reshape(B, S, -1), p["wo"])


def _cross_attention(cfg: ModelConfig, p: Params, x, enc_k, enc_v) -> jax.Array:
    """x (B, Sq, D); enc_k/enc_v (B, Sk, H, hd) precomputed."""
    B, Sq, _ = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = L.linear(x, p["wq"]).reshape(B, Sq, H, hd)
    Sk = enc_k.shape[1]
    out = L._masked_softmax_attn(
        q, enc_k, enc_v, jnp.full((Sq,), Sk, jnp.int32), jnp.arange(Sk), None,
        f32_math=cfg.attn_f32,
    )
    return L.linear(out.reshape(B, Sq, -1), p["wo"])


def encode(cfg: ModelConfig, params: Params, frames: jax.Array,
           *, scan_unroll: bool = False) -> jax.Array:
    """frames (B, Tf, D) from the conv stub -> encoder output (B, Tf, D)."""
    x = frames.astype(cfg.jnp_dtype) + sinusoid(frames.shape[1], cfg.d_model).astype(cfg.jnp_dtype)
    x = shard(x, "batch", None, None)

    def body(x, lp):
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        x = x + _bidir_attention(cfg, lp["attn"], h)
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.ffn(cfg, lp["ffn"], h)
        return shard(x, "batch", None, None), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                        unroll=True if scan_unroll else 1)
    return L.rmsnorm(x, params["ln_enc"], cfg.norm_eps)


def forward_encdec(
    cfg: ModelConfig,
    params: Params,
    batch: dict[str, jax.Array],
    *,
    lut=None,
    backend: str = "auto",
    remat: str = "none",
    scan_unroll: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Teacher-forced: encode frames, decode tokens.  Returns (logits, 0)."""
    enc_out = encode(cfg, params, batch["frames"], scan_unroll=scan_unroll)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.jnp_dtype)
    x = x + sinusoid(S, cfg.d_model).astype(cfg.jnp_dtype)
    x = shard(x, "batch", None, None)
    H, hd = cfg.n_heads, cfg.hd

    def body(x, lp):
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L._qkv(cfg, lp["attn"], h)
        pos = jnp.arange(S)
        x = x + L.linear(
            L._masked_softmax_attn(q, k, v, pos, pos, None,
                                   f32_math=cfg.attn_f32).reshape(B, S, -1),
            lp["attn"]["wo"],
        )
        h = L.rmsnorm(x, lp["ln_x"], cfg.norm_eps)
        enc_k = L.linear(enc_out, lp["cross"]["wk"]).reshape(B, -1, H, hd)
        enc_v = L.linear(enc_out, lp["cross"]["wv"]).reshape(B, -1, H, hd)
        x = x + _cross_attention(cfg, lp["cross"], h, enc_k, enc_v)
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.ffn(cfg, lp["ffn"], h)
        return shard(x, "batch", None, None), None

    if remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"],
                        unroll=True if scan_unroll else 1)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]).astype(jnp.float32)
    return logits, jnp.float32(0.0)


def encdec_loss(cfg, params, batch, *, lut=None, backend="auto", remat="none",
                scan_unroll=False):
    logits, _ = forward_encdec(cfg, params, batch, backend=backend,
                               remat=remat, scan_unroll=scan_unroll)
    tokens = batch["tokens"]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(logp, tokens[:, 1:, None], axis=-1)[..., 0]
    return nll.mean()


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_encdec_caches(cfg: ModelConfig, batch: int, seq_len: int) -> list[Params]:
    """Self-attn KV per decoder layer + precomputed cross KV slots."""
    enc = cfg.encoder
    dt = cfg.jnp_dtype
    caches = []
    for _ in range(cfg.n_layers):
        caches.append({
            "k": jnp.zeros((batch, seq_len, cfg.n_kv_heads, cfg.hd), dt),
            "v": jnp.zeros((batch, seq_len, cfg.n_kv_heads, cfg.hd), dt),
            "xk": jnp.zeros((batch, enc.n_frames, cfg.n_heads, cfg.hd), dt),
            "xv": jnp.zeros((batch, enc.n_frames, cfg.n_heads, cfg.hd), dt),
        })
    return caches


def prefill_cross(cfg: ModelConfig, params: Params, frames: jax.Array,
                  caches: list[Params]) -> list[Params]:
    """Encode once and stage each decoder layer's cross K/V into its cache."""
    enc_out = encode(cfg, params, frames)
    B = frames.shape[0]
    H, hd = cfg.n_heads, cfg.hd
    out = []
    for i, c in enumerate(caches):
        lp = jax.tree.map(lambda a, i=i: a[i], params["dec_layers"])
        nc = dict(c)
        nc["xk"] = L.linear(enc_out, lp["cross"]["wk"]).reshape(B, -1, H, hd)
        nc["xv"] = L.linear(enc_out, lp["cross"]["wv"]).reshape(B, -1, H, hd)
        out.append(nc)
    return out


def decode_step_encdec(
    cfg: ModelConfig,
    params: Params,
    caches: list[Params],
    tokens: jax.Array,  # (B, 1)
    pos: jax.Array,     # ()
) -> tuple[jax.Array, list[Params]]:
    B = tokens.shape[0]
    x = params["embed"][tokens].astype(cfg.jnp_dtype)
    x = x + jax.lax.dynamic_slice_in_dim(
        sinusoid(caches[0]["k"].shape[1], cfg.d_model), pos, 1
    ).astype(cfg.jnp_dtype)[None]
    new_caches = []
    for i, cache in enumerate(caches):
        lp = jax.tree.map(lambda a, i=i: a[i], params["dec_layers"])
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        attn_out, upd = L.attention_decode(cfg, lp["attn"], h, cache, pos, None)
        x = x + attn_out
        h = L.rmsnorm(x, lp["ln_x"], cfg.norm_eps)
        x = x + _cross_attention(cfg, lp["cross"], h, cache["xk"], cache["xv"])
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.ffn(cfg, lp["ffn"], h)
        nc = dict(cache)
        nc.update(upd)
        new_caches.append(nc)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]).astype(jnp.float32)[:, 0]
    return logits, new_caches
