"""Model zoo public API: family-dispatched init / forward / loss / decode."""

from __future__ import annotations

from . import encdec, lm
from .config import SHAPES, ModelConfig, ShapeConfig


def init_model(cfg: ModelConfig, key):
    if cfg.family == "audio":
        return encdec.init_encdec(cfg, key)
    return lm.init_lm(cfg, key)


def loss_fn(cfg: ModelConfig):
    return encdec.encdec_loss if cfg.family == "audio" else lm.lm_loss


def forward_fn(cfg: ModelConfig):
    return encdec.forward_encdec if cfg.family == "audio" else lm.forward_lm


def init_caches(cfg: ModelConfig, batch: int, seq_len: int):
    if cfg.family == "audio":
        return encdec.init_encdec_caches(cfg, batch, seq_len)
    return lm.init_decode_caches(cfg, batch, seq_len)


def decode_fn(cfg: ModelConfig):
    return encdec.decode_step_encdec if cfg.family == "audio" else lm.decode_step


def init_paged_caches(cfg: ModelConfig, batch: int, n_pages: int,
                      page_size: int, max_len: int):
    """Page-pool decode caches for continuous batching (LM families only)."""
    if cfg.family == "audio":
        raise ValueError("continuous batching serves LM families only")
    return lm.init_paged_caches(cfg, batch, n_pages, page_size, max_len)


def decode_paged_fn(cfg: ModelConfig):
    """Per-slot-position decode step over paged caches (LM families only)."""
    if cfg.family == "audio":
        raise ValueError("continuous batching serves LM families only")
    return lm.decode_step_paged


__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "init_model",
    "loss_fn",
    "forward_fn",
    "init_caches",
    "decode_fn",
    "init_paged_caches",
    "decode_paged_fn",
]
