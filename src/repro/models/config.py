"""Unified model configuration for the architecture zoo.

One :class:`ModelConfig` drives every assigned architecture; family-specific
behaviour hangs off the optional sub-configs (``moe``, ``mla``, ``ssm``,
``rwkv``, ``encoder``, ``vision``).  Configs for the ten assigned
architectures live in :mod:`repro.configs` and are selected with
``--arch <id>`` by the launchers.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal

import jax.numpy as jnp

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    d_ff_expert: int
    n_shared: int = 0              # always-on shared experts (DeepSeek)
    router_noise: float = 0.0
    aux_loss_weight: float = 0.01  # load-balancing loss
    impl: str = "blocked"          # 'blocked' (capacity batched-matmul) |
    #                                'ragged' (lax.ragged_dot) — see §Perf
    capacity_factor: float = 1.25  # blocked impl: slots = T*K/E * cf


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int              # compressed KV width (c_kv)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16            # N
    d_inner: int | None = None     # defaults to d_model
    dt_rank: int = 32


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64           # rank of the data-dependent decay LoRA


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder (conv frontend stubbed to frame embeddings)."""

    n_layers: int = 4
    n_frames: int = 1500           # encoder positions after the conv stub


@dataclass(frozen=True)
class VisionConfig:
    """ViT frontend stub: precomputed patch embeddings + linear projector."""

    n_patches: int = 256
    d_vision: int = 1024


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None            # default d_model // n_heads
    # --- attention flavour ---
    qk_norm: bool = False
    sliding_window: int | None = None      # uniform SWA (Mixtral)
    local_global_every: int | None = None  # gemma3: every k-th layer global
    local_window: int | None = None        # window of the local layers
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # --- family sub-configs ---
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None
    encoder: EncoderConfig | None = None
    vision: VisionConfig | None = None
    # --- numerics ---
    dtype: str = "bfloat16"
    attn_f32: bool = True   # f32 QK^T/PV einsums (baseline); False = bf16
    #                         inputs with f32 accumulation (§Perf iteration)
    # --- approximate-arithmetic emulation (the paper's Layer B hook) ---
    approx_mlp: bool = False               # route MLP matmuls through the LUT
    approx_bits: int = 4                   # LUT operand width: 4 (W4A4 native)
    #                                        or 8 (W8A8, composed 256x256
    #                                        tables via repro.precision)

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def attn_free(self) -> bool:
        return self.rwkv is not None

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (bounded state / window)."""
        return (
            self.rwkv is not None
            or self.ssm is not None
            or self.sliding_window is not None
            or self.local_global_every is not None
        )

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def n_params(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        H, Hkv, hd = self.n_heads, self.n_kv_heads, self.hd
        total = V * D if self.tie_embeddings else 2 * V * D
        per_layer = 0
        if self.rwkv is not None:
            hw = self.rwkv.head_dim
            nh = D // hw
            per_layer += 4 * D * D + D * D  # r/k/v/g + out
            per_layer += 2 * D * self.rwkv.decay_lora  # decay lora
            per_layer += nh * hw  # u
            per_layer += D * F + F * D + D * D  # channel mix
        elif self.mla is not None:
            mla = self.mla
            qk = mla.qk_nope_head_dim + mla.qk_rope_head_dim
            per_layer += D * (mla.kv_lora_rank + mla.qk_rope_head_dim)
            per_layer += D * H * qk
            per_layer += mla.kv_lora_rank * H * (mla.qk_nope_head_dim + mla.v_head_dim)
            per_layer += H * mla.v_head_dim * D
        else:
            per_layer += D * H * hd + 2 * D * Hkv * hd + H * hd * D
        if self.ssm is not None:  # hybrid adds the SSM path on top of attn
            di = self.ssm.d_inner or D
            per_layer += D * di + di * (2 * self.ssm.state_dim) + di * D
        if self.moe is not None:
            mo = self.moe
            per_layer += D * mo.n_experts
            per_layer += mo.n_experts * 3 * D * mo.d_ff_expert
            per_layer += mo.n_shared * 3 * D * mo.d_ff_expert
        elif self.rwkv is None:
            per_layer += 3 * D * F
        if self.encoder is not None:
            enc_layer = 4 * D * D + 2 * D * F  # self-attn + gelu mlp
            total += self.encoder.n_layers * enc_layer
            per_layer += 4 * D * D  # decoder cross-attention
        if self.vision is not None:
            total += self.vision.d_vision * D  # projector
        return total + L * per_layer

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.n_params()
        mo = self.moe
        inactive = (mo.n_experts - mo.top_k) * 3 * self.d_model * mo.d_ff_expert
        return self.n_params() - self.n_layers * inactive

    def with_approx_mlp(self, bits: int = 4) -> "ModelConfig":
        """Route MLP matmuls through the approximate-multiplier LUT at the
        given operand width (4 = native W4A4, 8 = composed W8A8)."""
        return replace(self, approx_mlp=True, approx_bits=int(bits))


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape × step-kind) cell of the assignment matrix."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
